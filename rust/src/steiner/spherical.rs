//! The spherical (Miquelian inversive plane) family of Steiner systems
//! (Theorem 3 in the paper):
//!
//! Points are the projective line PG(1, q²) = F_{q²} ∪ {∞} (q² + 1 points).
//! The base block is the subline PG(1, q) = F_q ∪ {∞}, where F_q ⊂ F_{q²}
//! is the fixed field of the Frobenius x ↦ x^q. Blocks are the orbit of the
//! base block under PGL₂(q²) acting by Möbius transformations; the orbit has
//! |PGL₂(q²)| / |PGL₂(q)| = q(q²+1) blocks — exactly P, one per processor.
//!
//! We enumerate the orbit by BFS with the standard PGL₂ generators
//! x ↦ x+1, x ↦ g·x (g primitive), x ↦ 1/x.

use super::SteinerSystem;
use crate::gf::{prime_power, Gf};
use anyhow::{Context, Result};
use std::collections::{HashSet, VecDeque};

/// A point of PG(1, q^α): field element ids `0..q^α`, with `q^α` denoting ∞.
type Point = u64;

/// Build the Steiner (q²+1, q+1, 3) system for a prime power q — the α = 2
/// member of Theorem 3's family, the one the paper's balanced partition
/// uses (P = q(q²+1) = number of blocks).
pub fn spherical(q: u64) -> Result<SteinerSystem> {
    spherical_alpha(q, 2)
}

/// Theorem 3 in full generality: the Steiner (q^α + 1, q + 1, 3) system as
/// the PGL₂(q^α) orbit of PG(1, q) ⊂ PG(1, q^α), for any prime power q and
/// α ≥ 2. The orbit has (q^α+1)·q^α·(q^α−1) / ((q+1)q(q−1)) blocks.
///
/// Note: only α = 2 yields the paper's balanced processor assignment
/// (blocks = q(q²+1) = P and m(m−1) divisible by P); for α ≥ 3 the system
/// still partitions the off-diagonal tetrahedral blocks but the diagonal
/// assignment of §6.1.3 need not balance — `TetraPartition::from_steiner`
/// reports this explicitly.
pub fn spherical_alpha(q: u64, alpha: u32) -> Result<SteinerSystem> {
    anyhow::ensure!(alpha >= 2, "alpha must be >= 2 (alpha = 1 is trivial)");
    let (p, e) = prime_power(q).with_context(|| format!("q={q} must be a prime power"))?;
    let qa = q.pow(alpha);
    let f = Gf::new(qa).with_context(|| format!("building GF({qa})"))?;
    let inf: Point = qa;

    // Base block: the subline F_q ∪ {∞} = fixed points of x ↦ x^q, plus ∞.
    let mut base: Vec<Point> = f.subfield(e).into_iter().collect();
    base.push(inf);
    base.sort_unstable();
    debug_assert_eq!(base.len() as u64, q + 1);

    let g = f.generator();

    // Möbius generator actions on PG(1, q²).
    let translate = |x: Point| -> Point {
        if x == inf {
            inf
        } else {
            f.add(x, 1)
        }
    };
    let scale = |x: Point| -> Point {
        if x == inf {
            inf
        } else {
            f.mul(g, x)
        }
    };
    let invert = |x: Point| -> Point {
        if x == inf {
            0
        } else if x == 0 {
            inf
        } else {
            f.inv(x)
        }
    };

    let apply = |block: &[Point], map: &dyn Fn(Point) -> Point| -> Vec<Point> {
        let mut out: Vec<Point> = block.iter().map(|&x| map(x)).collect();
        out.sort_unstable();
        out
    };

    // BFS over the orbit of the base block.
    let mut seen: HashSet<Vec<Point>> = HashSet::new();
    let mut queue: VecDeque<Vec<Point>> = VecDeque::new();
    seen.insert(base.clone());
    queue.push_back(base);
    while let Some(block) = queue.pop_front() {
        for map in [&translate as &dyn Fn(Point) -> Point, &scale, &invert] {
            let img = apply(&block, map);
            if !seen.contains(&img) {
                seen.insert(img.clone());
                queue.push_back(img);
            }
        }
    }

    let expected = ((qa + 1) * qa * (qa - 1) / ((q + 1) * q * (q - 1))) as usize;
    anyhow::ensure!(
        seen.len() == expected,
        "orbit size {} != |PGL₂(q^α)|/|PGL₂(q)| = {expected} for q={q}, α={alpha} \
         (p={p}, e={e})",
        seen.len()
    );

    let blocks: Vec<Vec<usize>> = seen
        .into_iter()
        .map(|b| b.into_iter().map(|x| x as usize).collect())
        .collect();
    SteinerSystem::new((qa + 1) as usize, (q + 1) as usize, blocks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_block_is_closed_subline() {
        // For q=3: F_3 ∪ {∞} inside PG(1, 9) has 4 points.
        let s = spherical(3).unwrap();
        assert!(s.blocks.iter().all(|b| b.len() == 4));
    }

    #[test]
    fn orbit_count_equals_processor_count() {
        for q in [2u64, 3, 4, 5] {
            let s = spherical(q).unwrap();
            assert_eq!(s.num_blocks() as u64, q * (q * q + 1), "q={q}");
        }
    }

    #[test]
    fn every_point_in_lambda1_blocks() {
        // Lemma 5: each of the q²+1 points lies in q(q+1) blocks.
        let s = spherical(3).unwrap();
        for x in 0..s.m {
            assert_eq!(s.blocks_with_point(x).len(), 12);
        }
    }

    #[test]
    fn every_pair_in_lambda2_blocks() {
        // Lemma 4: each pair lies in q+1 blocks.
        let s = spherical(3).unwrap();
        for x in 0..s.m {
            for y in x + 1..s.m {
                assert_eq!(s.blocks_with_pair(x, y).len(), 4);
            }
        }
    }

    #[test]
    fn theorem3_general_alpha() {
        // α = 3, q = 2: Steiner (9, 3, 3) — every 3-subset of 9 points is a
        // block (the complete quadruple-free case): 9·8·7/(3·2·1) = 84.
        let s = spherical_alpha(2, 3).unwrap();
        assert_eq!((s.m, s.r), (9, 3));
        assert_eq!(s.num_blocks(), 84);
        s.verify().unwrap();
        // α = 3, q = 3: Steiner (28, 4, 3), 819 blocks.
        let s = spherical_alpha(3, 3).unwrap();
        assert_eq!((s.m, s.r), (28, 4));
        assert_eq!(s.num_blocks(), 819);
        s.verify().unwrap();
        // α = 4, q = 2: Steiner (17, 3, 3) = all triples of 17 points, 680.
        let s = spherical_alpha(2, 4).unwrap();
        assert_eq!((s.m, s.r, s.num_blocks()), (17, 3, 680));
        s.verify().unwrap();
    }

    #[test]
    fn alpha_one_rejected() {
        assert!(spherical_alpha(3, 1).is_err());
    }
}
