//! Steiner (m, r, 3) systems — the combinatorial engine behind the paper's
//! tetrahedral block partitions (§6).
//!
//! A Steiner (m, r, 3) system is a collection of r-subsets ("blocks") of
//! {0..m} such that every 3-subset of points lies in exactly one block
//! (Definition 2). Two constructions are provided:
//!
//! * [`spherical`] — the infinite family of Theorem 3: blocks are the orbit
//!   of the subline PG(1, q) ⊂ PG(1, q²) under PGL₂(q²), giving a
//!   (q²+1, q+1, 3) system with exactly q(q²+1) blocks — one per processor.
//! * [`sqs8`] — the unique S(3, 4, 8) (Steiner quadruple system on 8
//!   points): planes of AG(3, 2), i.e. 4-sets of F₂³ with zero XOR. This is
//!   the paper's Appendix A / Table 3 instance (P = 14).
//!
//! [`fixtures`] carries the paper's literal Tables 1–3 for fidelity checks.

pub mod fixtures;
mod spherical;

pub use spherical::{spherical, spherical_alpha};

use anyhow::{bail, Result};
use std::collections::HashMap;

/// A Steiner (m, r, 3) system over points `0..m`.
#[derive(Debug, Clone)]
pub struct SteinerSystem {
    /// Number of points.
    pub m: usize,
    /// Block size.
    pub r: usize,
    /// Blocks, each a sorted r-subset of `0..m`.
    pub blocks: Vec<Vec<usize>>,
}

impl SteinerSystem {
    /// Construct from raw blocks, sorting and sanity-checking arity.
    pub fn new(m: usize, r: usize, mut blocks: Vec<Vec<usize>>) -> Result<Self> {
        for b in &mut blocks {
            b.sort_unstable();
            if b.len() != r {
                bail!("block {:?} has size {} != r={}", b, b.len(), r);
            }
            if b.windows(2).any(|w| w[0] == w[1]) {
                bail!("block {:?} has repeated points", b);
            }
            if b.iter().any(|&x| x >= m) {
                bail!("block {:?} has out-of-range point (m={})", b, m);
            }
        }
        blocks.sort();
        Ok(SteinerSystem { m, r, blocks })
    }

    /// Number of blocks. For the spherical family this equals q(q²+1) = P.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// λ₂ (Lemma 4): every 2-subset of points appears in exactly
    /// (m-2)/(r-2) blocks.
    pub fn lambda2(&self) -> usize {
        (self.m - 2) / (self.r - 2)
    }

    /// λ₁ (Lemma 5): every point appears in exactly
    /// (m-1)(m-2)/((r-1)(r-2)) blocks.
    pub fn lambda1(&self) -> usize {
        (self.m - 1) * (self.m - 2) / ((self.r - 1) * (self.r - 2))
    }

    /// Verify the defining property (every 3-subset in exactly one block)
    /// plus the Lemma 4 / Lemma 5 replication counts. Exhaustive.
    pub fn verify(&self) -> Result<()> {
        let expected_blocks = self.m * (self.m - 1) * (self.m - 2)
            / (self.r * (self.r - 1) * (self.r - 2));
        if self.num_blocks() != expected_blocks {
            bail!(
                "block count {} != expected {} for ({}, {}, 3)",
                self.num_blocks(),
                expected_blocks,
                self.m,
                self.r
            );
        }
        // every 3-subset covered exactly once
        let mut seen: HashMap<(usize, usize, usize), usize> = HashMap::new();
        for (bi, b) in self.blocks.iter().enumerate() {
            for x in 0..b.len() {
                for y in x + 1..b.len() {
                    for z in y + 1..b.len() {
                        let key = (b[x], b[y], b[z]);
                        if let Some(&other) = seen.get(&key) {
                            bail!("triple {:?} in blocks {} and {}", key, other, bi);
                        }
                        seen.insert(key, bi);
                    }
                }
            }
        }
        let total_triples = self.m * (self.m - 1) * (self.m - 2) / 6;
        if seen.len() != total_triples {
            bail!("covered {} triples, expected {}", seen.len(), total_triples);
        }
        // replication numbers
        let mut per_point = vec![0usize; self.m];
        let mut per_pair: HashMap<(usize, usize), usize> = HashMap::new();
        for b in &self.blocks {
            for &x in b {
                per_point[x] += 1;
            }
            for x in 0..b.len() {
                for y in x + 1..b.len() {
                    *per_pair.entry((b[x], b[y])).or_insert(0) += 1;
                }
            }
        }
        let l1 = self.lambda1();
        if per_point.iter().any(|&c| c != l1) {
            bail!("per-point replication != λ₁ = {l1}: {:?}", per_point);
        }
        let l2 = self.lambda2();
        for i in 0..self.m {
            for j in i + 1..self.m {
                if per_pair.get(&(i, j)).copied().unwrap_or(0) != l2 {
                    bail!("pair ({i},{j}) replication != λ₂ = {l2}");
                }
            }
        }
        Ok(())
    }

    /// Blocks containing a given point.
    pub fn blocks_with_point(&self, x: usize) -> Vec<usize> {
        (0..self.blocks.len())
            .filter(|&b| self.blocks[b].contains(&x))
            .collect()
    }

    /// Blocks containing both given points.
    pub fn blocks_with_pair(&self, x: usize, y: usize) -> Vec<usize> {
        (0..self.blocks.len())
            .filter(|&b| self.blocks[b].contains(&x) && self.blocks[b].contains(&y))
            .collect()
    }
}

/// The trivial Steiner (m, 3, 3) system: every 3-subset of points is its
/// own block, so each 3-subset lies in exactly one block by construction.
/// Exists for every m ≥ 3 with P = C(m, 3) blocks — it fills in processor
/// counts the named families skip (e.g. P = 4 at m = 4, which the E12
/// overlap bench sweeps; m = 5 reproduces the spherical q = 2 system).
/// Not communication-efficient at scale (λ₁ = (m−1)(m−2)/2 processors
/// share every row block), but the partition machinery is
/// family-agnostic. Note the tetrahedral partition additionally needs
/// m(m−1) divisible by C(m, 3) for the balanced diagonal assignment —
/// m ∈ {3, 4, 5} qualify.
pub fn trivial(m: usize) -> Result<SteinerSystem> {
    if m < 3 {
        bail!("trivial Steiner system needs m >= 3 points, got {m}");
    }
    let mut blocks = Vec::new();
    for a in 0..m {
        for b in a + 1..m {
            for c in b + 1..m {
                blocks.push(vec![a, b, c]);
            }
        }
    }
    SteinerSystem::new(m, 3, blocks)
}

/// The unique Steiner quadruple system S(3, 4, 8): points are the vectors of
/// F₂³ (ids 0..8), blocks are the 14 affine planes {a, b, c, a⊕b⊕c}.
///
/// This is the system behind the paper's Table 3 / Figure 1 example (m = 8,
/// P = 14); it is *not* in the spherical family (q² = 7 is not a prime-power
/// square) but the partition machinery is family-agnostic.
pub fn sqs8() -> SteinerSystem {
    let mut blocks = Vec::new();
    for a in 0usize..8 {
        for b in a + 1..8 {
            for c in b + 1..8 {
                let d = a ^ b ^ c;
                // each plane is emitted once: from its three smallest points
                if d > c {
                    blocks.push(vec![a, b, c, d]);
                }
            }
        }
    }
    SteinerSystem::new(8, 4, blocks).expect("SQS(8) construction")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sqs8_is_a_steiner_system() {
        let s = sqs8();
        assert_eq!(s.m, 8);
        assert_eq!(s.r, 4);
        assert_eq!(s.num_blocks(), 14);
        assert_eq!(s.lambda1(), 7);
        assert_eq!(s.lambda2(), 3);
        s.verify().unwrap();
    }

    #[test]
    fn sqs8_blocks_intersect_in_0_or_2_points() {
        // The property behind Figure 1's 12-step schedule: any two distinct
        // quadruples of SQS(8) share exactly 0 or 2 points.
        let s = sqs8();
        for i in 0..s.blocks.len() {
            for j in i + 1..s.blocks.len() {
                let shared = s.blocks[i]
                    .iter()
                    .filter(|x| s.blocks[j].contains(x))
                    .count();
                assert!(shared == 0 || shared == 2, "blocks {i},{j} share {shared}");
            }
        }
    }

    #[test]
    fn trivial_systems_verify() {
        for m in [3usize, 4, 5, 6] {
            let s = trivial(m).unwrap();
            assert_eq!(s.m, m);
            assert_eq!(s.r, 3);
            assert_eq!(s.num_blocks(), m * (m - 1) * (m - 2) / 6);
            s.verify().unwrap();
        }
        assert!(trivial(2).is_err());
    }

    #[test]
    fn trivial_m4_partitions_into_p4() {
        // The P = 4 instance the E12 overlap bench uses: 4 processors, 3
        // non-central diagonal blocks each, all 20 lower-tetra blocks
        // covered once (partition verify), schedulable.
        let part = crate::partition::TetraPartition::from_steiner(&trivial(4).unwrap()).unwrap();
        assert_eq!((part.m, part.p), (4, 4));
        part.verify().unwrap();
        for p in 0..part.p {
            assert_eq!(part.n_p[p].len(), 3);
            assert_eq!(part.offdiag_blocks(p).len(), 1);
        }
        let sched = crate::schedule::CommSchedule::build(&part).unwrap();
        sched.validate(&part).unwrap();
    }

    #[test]
    fn spherical_q2_properties() {
        let s = spherical(2).unwrap();
        assert_eq!(s.m, 5);
        assert_eq!(s.r, 3);
        assert_eq!(s.num_blocks(), 10); // q(q²+1) = 2*5
        s.verify().unwrap();
    }

    #[test]
    fn spherical_q3_matches_paper_table1_shape() {
        let s = spherical(3).unwrap();
        assert_eq!(s.m, 10);
        assert_eq!(s.r, 4);
        assert_eq!(s.num_blocks(), 30); // P = 30, the paper's Table 1
        assert_eq!(s.lambda1(), 12); // q(q+1) = |Q_i| in Table 2
        assert_eq!(s.lambda2(), 4); // q+1
        s.verify().unwrap();
    }

    #[test]
    fn spherical_q4_and_q5() {
        let s4 = spherical(4).unwrap();
        assert_eq!((s4.m, s4.r, s4.num_blocks()), (17, 5, 68));
        s4.verify().unwrap();
        let s5 = spherical(5).unwrap();
        assert_eq!((s5.m, s5.r, s5.num_blocks()), (26, 6, 130));
        s5.verify().unwrap();
    }

    #[test]
    fn spherical_prime_power_q() {
        // q = 7 (prime), q = 8 = 2³, q = 9 = 3² all exist.
        for (q, m, p) in [(7u64, 50usize, 350usize), (8, 65, 520), (9, 82, 738)] {
            let s = spherical(q).unwrap();
            assert_eq!((s.m, s.num_blocks()), (m, p), "q={q}");
            s.verify().unwrap();
        }
    }

    #[test]
    fn spherical_rejects_non_prime_power() {
        assert!(spherical(6).is_err());
        assert!(spherical(10).is_err());
    }

    #[test]
    fn paper_table1_fixture_is_a_steiner_system() {
        let s = fixtures::table1_system();
        assert_eq!((s.m, s.r, s.num_blocks()), (10, 4, 30));
        s.verify().unwrap();
    }

    #[test]
    fn paper_table3_fixture_is_a_steiner_system() {
        let s = fixtures::table3_system();
        assert_eq!((s.m, s.r, s.num_blocks()), (8, 4, 14));
        s.verify().unwrap();
    }

    #[test]
    fn our_sqs8_isomorphism_invariants_match_table3() {
        // Constructions may differ by point relabeling; compare the full
        // invariant profile instead.
        let ours = sqs8();
        let paper = fixtures::table3_system();
        assert_eq!(ours.m, paper.m);
        assert_eq!(ours.r, paper.r);
        assert_eq!(ours.num_blocks(), paper.num_blocks());
        assert_eq!(ours.lambda1(), paper.lambda1());
        assert_eq!(ours.lambda2(), paper.lambda2());
        // intersection-size distribution is an isomorphism invariant
        let dist = |s: &SteinerSystem| {
            let mut d = [0usize; 5];
            for i in 0..s.blocks.len() {
                for j in i + 1..s.blocks.len() {
                    let shared = s.blocks[i]
                        .iter()
                        .filter(|x| s.blocks[j].contains(x))
                        .count();
                    d[shared] += 1;
                }
            }
            d
        };
        assert_eq!(dist(&ours), dist(&paper));
    }
}
