//! The paper's literal Tables 1–3, transcribed as fixtures.
//!
//! These let us validate our own constructions against the exact published
//! instances: Table 1/2 (Steiner (10,4,3) partition, m = 10, P = 30) and
//! Table 3 (Steiner (8,4,3) partition, m = 8, P = 14). All data here is
//! 1-indexed in the paper; we store 0-indexed.

use super::SteinerSystem;

/// A full published partition row: (R_p, N_p, D_p).
pub struct PaperRow {
    /// Index set of the tetrahedral block (the Steiner block), 0-indexed.
    pub r_p: Vec<usize>,
    /// Non-central diagonal blocks (a,a,b) / (a,b,b) assigned, 0-indexed.
    pub n_p: Vec<(usize, usize, usize)>,
    /// Central diagonal block (a,a,a) if assigned, 0-indexed.
    pub d_p: Option<usize>,
}

fn row(r: &[usize], n: &[(usize, usize, usize)], d: Option<usize>) -> PaperRow {
    PaperRow {
        r_p: r.iter().map(|x| x - 1).collect(),
        n_p: n.iter().map(|&(a, b, c)| (a - 1, b - 1, c - 1)).collect(),
        d_p: d.map(|x| x - 1),
    }
}

/// Table 1: processor sets of the tetrahedral block partition for m = 10,
/// P = 30 (spherical q = 3).
pub fn table1() -> Vec<PaperRow> {
    vec![
        row(&[1, 2, 3, 7], &[(2, 2, 1), (2, 1, 1), (7, 2, 2)], Some(1)),
        row(&[1, 2, 4, 5], &[(4, 4, 1), (4, 1, 1), (5, 1, 1)], Some(2)),
        row(&[1, 2, 6, 10], &[(6, 6, 1), (10, 10, 2), (6, 1, 1)], Some(6)),
        row(&[1, 2, 8, 9], &[(8, 8, 1), (9, 9, 8), (8, 1, 1)], Some(8)),
        row(&[1, 3, 4, 10], &[(10, 10, 1), (10, 10, 3), (10, 1, 1)], Some(3)),
        row(&[1, 3, 5, 8], &[(3, 3, 1), (8, 8, 5), (3, 1, 1)], Some(5)),
        row(&[1, 3, 6, 9], &[(9, 9, 1), (9, 9, 3), (9, 1, 1)], Some(9)),
        row(&[1, 4, 6, 8], &[(6, 6, 4), (8, 8, 6), (6, 4, 4)], Some(4)),
        row(&[1, 4, 7, 9], &[(7, 7, 1), (9, 9, 4), (7, 1, 1)], Some(7)),
        row(&[1, 5, 6, 7], &[(5, 5, 1), (7, 7, 6), (7, 6, 6)], None),
        row(&[1, 5, 9, 10], &[(9, 9, 5), (10, 10, 9), (9, 5, 5)], Some(10)),
        row(&[1, 7, 8, 10], &[(8, 8, 7), (10, 10, 8), (10, 8, 8)], None),
        row(&[2, 3, 4, 8], &[(3, 3, 2), (3, 2, 2), (4, 2, 2)], None),
        row(&[2, 3, 5, 6], &[(5, 5, 2), (5, 2, 2), (6, 5, 5)], None),
        row(&[2, 3, 9, 10], &[(9, 9, 2), (9, 2, 2), (10, 2, 2)], None),
        row(&[2, 4, 6, 9], &[(4, 4, 2), (9, 9, 6), (9, 6, 6)], None),
        row(&[2, 4, 7, 10], &[(7, 7, 2), (10, 10, 4), (10, 4, 4)], None),
        row(&[2, 5, 7, 9], &[(7, 7, 5), (9, 9, 7), (7, 5, 5)], None),
        row(&[2, 5, 8, 10], &[(8, 8, 2), (8, 2, 2), (10, 5, 5)], None),
        row(&[2, 6, 7, 8], &[(6, 6, 2), (6, 2, 2), (8, 6, 6)], None),
        row(&[3, 4, 5, 9], &[(4, 4, 3), (4, 3, 3), (9, 4, 4)], None),
        row(&[3, 4, 6, 7], &[(6, 6, 3), (6, 3, 3), (7, 3, 3)], None),
        row(&[3, 5, 7, 10], &[(5, 5, 3), (5, 3, 3), (10, 3, 3)], None),
        row(&[3, 6, 8, 10], &[(8, 8, 3), (10, 10, 6), (8, 3, 3)], None),
        row(&[3, 7, 8, 9], &[(7, 7, 3), (9, 7, 7), (9, 3, 3)], None),
        row(&[4, 5, 6, 10], &[(5, 5, 4), (5, 4, 4), (10, 10, 5)], None),
        row(&[4, 5, 7, 8], &[(7, 7, 4), (7, 4, 4), (8, 7, 7)], None),
        row(&[4, 8, 9, 10], &[(8, 8, 4), (8, 4, 4), (10, 9, 9)], None),
        row(&[5, 6, 8, 9], &[(6, 6, 5), (8, 5, 5), (9, 8, 8)], None),
        row(&[6, 7, 9, 10], &[(10, 6, 6), (10, 10, 7), (10, 7, 7)], None),
    ]
}

/// Table 2: Q_i row-block sets for the Table 1 partition (1-indexed in the
/// paper; 0-indexed here). Row block i is distributed over processors Q_i.
pub fn table2() -> Vec<Vec<usize>> {
    let raw: Vec<Vec<usize>> = vec![
        vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12],
        vec![1, 2, 3, 4, 13, 14, 15, 16, 17, 18, 19, 20],
        vec![1, 5, 6, 7, 13, 14, 15, 21, 22, 23, 24, 25],
        vec![2, 5, 8, 9, 13, 16, 17, 21, 22, 26, 27, 28],
        vec![2, 6, 10, 11, 14, 18, 19, 21, 23, 26, 27, 29],
        vec![3, 7, 8, 10, 14, 16, 20, 22, 24, 26, 29, 30],
        vec![1, 9, 10, 12, 17, 18, 20, 22, 23, 25, 27, 30],
        vec![4, 6, 8, 12, 13, 19, 20, 24, 25, 27, 28, 29],
        vec![4, 7, 9, 11, 15, 16, 18, 21, 25, 28, 29, 30],
        vec![3, 5, 11, 12, 15, 17, 19, 23, 24, 26, 28, 30],
    ];
    raw.into_iter()
        .map(|q| q.into_iter().map(|p| p - 1).collect())
        .collect()
}

/// Table 3: the Steiner (8,4,3) partition for m = 8, P = 14 (Appendix A).
pub fn table3() -> Vec<PaperRow> {
    vec![
        row(
            &[1, 2, 3, 4],
            &[(2, 2, 1), (3, 3, 2), (2, 1, 1), (3, 2, 2)],
            Some(1),
        ),
        row(
            &[1, 2, 5, 6],
            &[(5, 5, 1), (6, 6, 1), (5, 1, 1), (5, 2, 2)],
            Some(2),
        ),
        row(
            &[1, 2, 7, 8],
            &[(7, 7, 1), (8, 8, 1), (7, 1, 1), (7, 2, 2)],
            Some(7),
        ),
        row(
            &[1, 3, 5, 7],
            &[(7, 7, 3), (7, 7, 5), (3, 1, 1), (7, 3, 3)],
            Some(3),
        ),
        row(
            &[1, 3, 6, 8],
            &[(6, 6, 3), (3, 3, 1), (6, 1, 1), (8, 1, 1)],
            Some(6),
        ),
        row(
            &[1, 4, 5, 8],
            &[(8, 8, 4), (5, 5, 4), (4, 1, 1), (5, 4, 4)],
            Some(5),
        ),
        row(
            &[1, 4, 6, 7],
            &[(7, 7, 4), (4, 4, 1), (6, 4, 4), (7, 6, 6)],
            Some(4),
        ),
        row(
            &[2, 3, 5, 8],
            &[(8, 8, 5), (5, 5, 3), (5, 3, 3), (8, 2, 2)],
            Some(8),
        ),
        row(
            &[2, 3, 6, 7],
            &[(6, 6, 2), (7, 7, 2), (6, 2, 2), (6, 3, 3)],
            None,
        ),
        row(
            &[2, 4, 5, 7],
            &[(5, 5, 2), (4, 4, 2), (4, 2, 2), (7, 4, 4)],
            None,
        ),
        row(
            &[2, 4, 6, 8],
            &[(8, 8, 2), (8, 8, 6), (8, 4, 4), (8, 6, 6)],
            None,
        ),
        row(
            &[3, 4, 5, 6],
            &[(6, 6, 4), (4, 4, 3), (4, 3, 3), (6, 5, 5)],
            None,
        ),
        row(
            &[3, 4, 7, 8],
            &[(8, 8, 3), (8, 8, 7), (8, 3, 3), (8, 7, 7)],
            None,
        ),
        row(
            &[5, 6, 7, 8],
            &[(6, 6, 5), (7, 7, 6), (7, 5, 5), (8, 5, 5)],
            None,
        ),
    ]
}

/// The Table 1 R_p sets as a SteinerSystem (m = 10, r = 4).
pub fn table1_system() -> SteinerSystem {
    SteinerSystem::new(10, 4, table1().into_iter().map(|r| r.r_p).collect())
        .expect("Table 1 fixture")
}

/// The Table 3 R_p sets as a SteinerSystem (m = 8, r = 4).
pub fn table3_system() -> SteinerSystem {
    SteinerSystem::new(8, 4, table3().into_iter().map(|r| r.r_p).collect())
        .expect("Table 3 fixture")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_is_derivable_from_table1() {
        // Q_i = { p : i ∈ R_p } — the paper's Table 2 must be exactly the
        // point-incidence sets of Table 1.
        let rows = table1();
        let q = table2();
        for i in 0..10 {
            let derived: Vec<usize> = (0..rows.len())
                .filter(|&p| rows[p].r_p.contains(&i))
                .collect();
            assert_eq!(derived, q[i], "Q_{}", i + 1);
        }
    }

    #[test]
    fn table1_diagonal_assignment_is_valid() {
        // N_p blocks only use indices with both values in R_p; D_p central
        // index must be in R_p; all diagonal blocks covered exactly once.
        let rows = table1();
        let mut noncentral = std::collections::HashSet::new();
        let mut central = std::collections::HashSet::new();
        for r in &rows {
            for &(a, b, c) in &r.n_p {
                assert!(a >= b && b >= c && (a == b || b == c) && a != c);
                assert!(r.r_p.contains(&a) && r.r_p.contains(&c), "{:?}", (a, b, c));
                assert!(noncentral.insert((a, b, c)), "dup noncentral {:?}", (a, b, c));
            }
            if let Some(d) = r.d_p {
                assert!(r.r_p.contains(&d));
                assert!(central.insert(d), "dup central {d}");
            }
        }
        assert_eq!(noncentral.len(), 90); // m(m-1) = 10*9
        assert_eq!(central.len(), 10); // m
    }

    #[test]
    fn table3_diagonal_assignment_is_valid() {
        let rows = table3();
        let mut noncentral = std::collections::HashSet::new();
        let mut central = std::collections::HashSet::new();
        for r in &rows {
            for &(a, b, c) in &r.n_p {
                assert!(a >= b && b >= c && (a == b || b == c) && a != c);
                assert!(r.r_p.contains(&a) && r.r_p.contains(&c), "{:?}", (a, b, c));
                assert!(noncentral.insert((a, b, c)), "dup noncentral {:?}", (a, b, c));
            }
            if let Some(d) = r.d_p {
                assert!(r.r_p.contains(&d));
                assert!(central.insert(d));
            }
        }
        assert_eq!(noncentral.len(), 56); // m(m-1) = 8*7
        assert_eq!(central.len(), 8);
    }
}
