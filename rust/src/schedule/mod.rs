//! Communication schedules for Algorithm 5 (§7.2).
//!
//! Two processors must exchange vector data iff their Steiner index sets
//! intersect; the payload of the (p → p′) message is p's own portions of
//! every shared row block. The paper (Theorem 6) shows all transfers fit in
//! Δ steps where each processor sends ≤ 1 and receives ≤ 1 message per step,
//! with Δ = q³/2 + 3q²/2 − 1 for the spherical family (and 12 for the
//! Table 3 / Figure 1 SQS(8) instance).
//!
//! We realize Theorem 6 constructively: the directed message multigraph is
//! padded to Δ-regular and peeled into Δ perfect matchings (König), exactly
//! as in `matching::bipartite_edge_coloring`.

use crate::matching::{bipartite_edge_coloring, BipartiteMultiGraph};
use crate::partition::TetraPartition;
use anyhow::Result;

/// One directed point-to-point transfer: `from` sends its own portions of
/// the listed row blocks to `to`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Xfer {
    pub from: usize,
    pub to: usize,
    /// Row blocks shared between the two processors (sorted).
    pub row_blocks: Vec<usize>,
}

impl Xfer {
    /// Words carried by this message for row-block length b: the sender's
    /// portion of each shared row block.
    pub fn words(&self, part: &TetraPartition, b: usize) -> usize {
        self.row_blocks
            .iter()
            .map(|&i| part.portion(i, self.from, b).len())
            .sum()
    }
}

/// A stepped point-to-point communication schedule (one vector phase).
#[derive(Debug, Clone)]
pub struct CommSchedule {
    /// All required transfers.
    pub xfers: Vec<Xfer>,
    /// Steps: indices into `xfers`; within a step every processor sends at
    /// most one and receives at most one message (the paper's model).
    pub steps: Vec<Vec<usize>>,
}

impl CommSchedule {
    /// Build the point-to-point schedule for a partition (Theorem 6).
    pub fn build(part: &TetraPartition) -> Result<CommSchedule> {
        let mut xfers = Vec::new();
        for p in 0..part.p {
            for p2 in 0..part.p {
                if p == p2 {
                    continue;
                }
                let shared: Vec<usize> = part.r_p[p]
                    .iter()
                    .copied()
                    .filter(|i| part.r_p[p2].contains(i))
                    .collect();
                if !shared.is_empty() {
                    xfers.push(Xfer {
                        from: p,
                        to: p2,
                        row_blocks: shared,
                    });
                }
            }
        }
        let graph = BipartiteMultiGraph {
            n: part.p,
            edges: xfers
                .iter()
                .enumerate()
                .map(|(id, x)| (x.from, x.to, id))
                .collect(),
        };
        let steps = bipartite_edge_coloring(&graph)?;
        Ok(CommSchedule { xfers, steps })
    }

    /// Number of communication steps.
    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    /// Maximum words sent (== received, by symmetry of the transfer set) by
    /// any processor over the whole schedule, for row-block length b.
    pub fn max_words_per_proc(&self, part: &TetraPartition, b: usize) -> usize {
        let mut sent = vec![0usize; part.p];
        for x in &self.xfers {
            sent[x.from] += x.words(part, b);
        }
        sent.into_iter().max().unwrap_or(0)
    }

    /// Validate the schedule against the α-β-γ model and the partition:
    /// every required transfer appears exactly once, and per step each
    /// processor sends ≤ 1 and receives ≤ 1 message.
    pub fn validate(&self, part: &TetraPartition) -> Result<()> {
        use anyhow::bail;
        let mut seen = vec![false; self.xfers.len()];
        for (si, step) in self.steps.iter().enumerate() {
            let mut sending = vec![false; part.p];
            let mut receiving = vec![false; part.p];
            for &xi in step {
                let x = &self.xfers[xi];
                if sending[x.from] {
                    bail!("step {si}: processor {} sends twice", x.from);
                }
                if receiving[x.to] {
                    bail!("step {si}: processor {} receives twice", x.to);
                }
                sending[x.from] = true;
                receiving[x.to] = true;
                if seen[xi] {
                    bail!("transfer {xi} scheduled twice");
                }
                seen[xi] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            bail!("not all transfers scheduled");
        }
        // completeness: every pair with shared row blocks exchanges both ways
        for p in 0..part.p {
            for p2 in 0..part.p {
                if p == p2 {
                    continue;
                }
                let shared: Vec<usize> = part.r_p[p]
                    .iter()
                    .copied()
                    .filter(|i| part.r_p[p2].contains(i))
                    .collect();
                let found = self
                    .xfers
                    .iter()
                    .filter(|x| x.from == p && x.to == p2)
                    .count();
                if shared.is_empty() && found != 0 {
                    bail!("spurious transfer {p} -> {p2}");
                }
                if !shared.is_empty() {
                    if found != 1 {
                        bail!("expected 1 transfer {p} -> {p2}, found {found}");
                    }
                    let x = self
                        .xfers
                        .iter()
                        .find(|x| x.from == p && x.to == p2)
                        .unwrap();
                    if x.row_blocks != shared {
                        bail!("transfer {p} -> {p2} carries wrong row blocks");
                    }
                }
            }
        }
        Ok(())
    }
}

/// Bandwidth cost per processor of the All-to-All formulation (§7.2.2),
/// for ONE vector phase: the collective runs P−1 steps with a uniform
/// per-step buffer of λ₂−1... — concretely, the paper's accounting: at each
/// of the P−1 steps a processor may send its own data of up to 2 row blocks,
/// i.e. `2·b/λ₁` words, giving `2b/λ₁·(P−1)` words per vector.
pub fn alltoall_words_per_vector(part: &TetraPartition, b: usize) -> usize {
    let lambda1 = part.lambda1();
    2 * b.div_ceil(lambda1) * (part.p - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::steiner::{fixtures, spherical, sqs8};

    fn schedule_for(sys: &crate::steiner::SteinerSystem) -> (TetraPartition, CommSchedule) {
        let part = TetraPartition::from_steiner(sys).unwrap();
        let sched = CommSchedule::build(&part).unwrap();
        sched.validate(&part).unwrap();
        (part, sched)
    }

    #[test]
    fn sqs8_schedule_has_12_steps_like_figure1() {
        // Figure 1: all transfers for the Table 3 partition complete in 12
        // steps (< P-1 = 13).
        let (_, sched) = schedule_for(&sqs8());
        assert_eq!(sched.num_steps(), 12);
    }

    #[test]
    fn paper_table3_partition_also_schedules_in_12_steps() {
        let part = TetraPartition::from_rows(8, &fixtures::table3()).unwrap();
        let sched = CommSchedule::build(&part).unwrap();
        sched.validate(&part).unwrap();
        assert_eq!(sched.num_steps(), 12);
    }

    #[test]
    fn spherical_step_counts_match_formula() {
        // §7.2: q³/2 + 3q²/2 − 1 steps.
        for q in [2usize, 3] {
            let s = spherical(q as u64).unwrap();
            let (_, sched) = schedule_for(&s);
            let expected = q * q * (q + 3) / 2 - 1; // q³/2 + 3q²/2 − 1
            assert_eq!(sched.num_steps(), expected, "q={q}");
        }
    }

    #[test]
    fn partner_counts_match_paper() {
        // Each processor communicates 2 row blocks with q²(q+1)/2 partners
        // and 1 row block with q²−1 partners (§7.2.2).
        let q = 3usize;
        let s = spherical(q as u64).unwrap();
        let (part, sched) = schedule_for(&s);
        for p in 0..part.p {
            let outgoing: Vec<&Xfer> = sched.xfers.iter().filter(|x| x.from == p).collect();
            let two = outgoing.iter().filter(|x| x.row_blocks.len() == 2).count();
            let one = outgoing.iter().filter(|x| x.row_blocks.len() == 1).count();
            assert_eq!(two, q * q * (q + 1) / 2, "proc {p} two-block partners");
            assert_eq!(one, q * q - 1, "proc {p} one-block partners");
            assert_eq!(outgoing.len(), two + one);
        }
    }

    #[test]
    fn words_per_proc_match_closed_form() {
        // Each processor sends n(q+1)/(q²+1) − n/P words per vector (§7.2.2)
        // when λ₁ divides b.
        for q in [2usize, 3] {
            let s = spherical(q as u64).unwrap();
            let (part, sched) = schedule_for(&s);
            let lambda1 = q * (q + 1);
            let b = 2 * lambda1; // divisible
            let n = b * part.m;
            let expected = n * (q + 1) / (q * q + 1) - n / part.p;
            for p in 0..part.p {
                let sent: usize = sched
                    .xfers
                    .iter()
                    .filter(|x| x.from == p)
                    .map(|x| x.words(&part, b))
                    .sum();
                assert_eq!(sent, expected, "q={q} proc {p}");
            }
        }
    }

    #[test]
    fn alltoall_cost_matches_formula() {
        // §7.2.2: 2b/(q(q+1)) · (P−1) words per vector.
        let q = 3usize;
        let s = spherical(q as u64).unwrap();
        let part = TetraPartition::from_steiner(&s).unwrap();
        let b = 2 * q * (q + 1);
        let w = alltoall_words_per_vector(&part, b);
        assert_eq!(w, 2 * b / (q * (q + 1)) * (part.p - 1));
    }
}
