//! Randomized end-to-end property tests over the distributed stack
//! (seeded, deterministic — see `util::proptest`).
//!
//! P1: for every supported partition and random tensor/vector, the
//!     distributed Algorithm 5 result equals the sequential Algorithm 4
//!     oracle (both comm modes, batched and unbatched).
//! P2: communication counters equal the §7.2.2 closed form *exactly*
//!     whenever λ₁ | b, for every processor (not just the max).
//! P3: total logical ternary multiplications equal n²(n+1)/2 regardless of
//!     the partition (no work duplicated or dropped).
//! P4: schedules remain valid for random mixes of q and the SQS(8) system.
//! P5: the batched multi-RHS path (`SttsvPlan::run_multi`) matches r
//!     independent oracle calls column-by-column across all three block
//!     kinds and both comm modes, with words exactly r× and messages
//!     independent of r.
//! P6: the zero-copy packed path and the dense-extract path agree on
//!     random partitions for r ∈ {1, 4}, and the packed plan holds no
//!     dense tensor copies.
//! P7: the ternary multiplications the packed kernels execute equal the
//!     §7.1 logical accounting (`block_ternary_mults`) summed per
//!     processor — the packed path never overshoots on diagonal blocks.
//! P8: the overlapped pipeline matches the phased oracle within 1e-4 on
//!     random partitions/modes for r ∈ {1, 4} AND its per-processor
//!     CommStats (words and messages, sent and received) are *exactly*
//!     equal to the phased path's in both PointToPoint and AllToAll — the
//!     α-β-γ model cost is invariant under overlap; steady-state reruns
//!     allocate zero payload buffers.
//! P9: a k-iteration resident solver session equals k independent
//!     `plan.run`/`plan.run_multi` calls plus host scalar arithmetic
//!     (values within 1e-4), while its per-processor CommStats equal
//!     EXACTLY k × one phased STTSV + k × the recursive-doubling
//!     collective closed form — in both comm modes, for the power driver
//!     (r = 1) and the CP driver (r = 4); workers are spawned once per
//!     solve, and no host↔worker vector traffic exists between
//!     iterations for the comm counters to miss.
//! P10: the compiled sweep-program path (plan-built run descriptors +
//!     register-tiled microkernels) matches the interpreted packed plan
//!     within 1e-4 — and BITWISE on the phased path at
//!     compute_threads = 1 — for r ∈ {1, 4}, both comm modes, phased and
//!     overlap, on random partitions; per-processor words, messages, and
//!     charged ternary mults are exactly invariant, the compiled plan
//!     holds zero extra resident tensor words, and a 4-thread compute
//!     pool changes no CommStats counter.
//! P11: the lock-free SPSC transport is observationally identical to the
//!     mpsc counting oracle — per-processor words, messages, and charged
//!     ternary mults are bitwise equal across both comm modes, phased and
//!     overlap, r ∈ {1, 4}; phased results are bitwise transport-invariant
//!     and overlap results agree within f32 reassociation tolerance.
//! P12: N queries coalesced into r-deep sweeps by the serving layer are
//!     bitwise the same-depth `run_multi` oracle on the phased path (the
//!     demux adds nothing) and within 1e-4 of N serial `plan.run` calls on
//!     both phased and overlap (the r = 1 scalar kernels and r ≥ 2 fused
//!     multi kernels regroup central-block tail adds — the documented P10
//!     kernel-family boundary — so cross-depth equality is tolerance, not
//!     bitwise); the serial admission policy IS bitwise `plan.run`; every
//!     batch's per-processor counters equal exactly one r-deep STTSV
//!     (words r×, messages unchanged vs r = 1); and the plan cache's
//!     `plan_builds` counter freezes after warmup — a second drain through
//!     the same server builds nothing.
//! P13: chaos soak (§Rob) — under seeded fault injection (delays,
//!     reordering, transient failures, rank crashes) across ≥32 seeds ×
//!     {phased, overlap} × {p2p, a2a}, every run TERMINATES: either Ok
//!     with oracle-equal results (bitwise on the phased path, 2e-4 under
//!     overlap) and unchanged CommStats, or a typed `FailureReport`
//!     naming a real rank — never a hang, never a panic — and the same
//!     plan then completes a clean rerun bitwise (pools survive the
//!     poison). A zero-fault `ChaosTransport` (non-default plan, zero
//!     rate) is observationally invisible: bitwise results and identical
//!     per-proc CommStats on both transports, both comm modes. Crashed
//!     resident solves under a checkpointed `RecoveryPolicy` recover to
//!     the fault-free answer bitwise; without recovery they surface the
//!     typed report instead of hanging.
//! P14: the bf16 wire format (§Perf, PR 9) is an ENCODING, not an
//!     algorithm change — under `wire = bf16` every per-processor word and
//!     message count is bitwise the f32 wire's while payload bytes are
//!     exactly halved (both matching the wire-aware
//!     `expected_proc_stats` closed form), on both transports × both comm
//!     modes × r ∈ {1, 4}; results agree with the f32 phased oracle
//!     within 2⁻⁷ of the column scale (≤ 2⁻⁸ relative rounding per wire
//!     crossing). And the pinned configuration `wire = f32` +
//!     `simd = scalar` is bitwise the default path — the regression pin
//!     that licenses AVX2 auto-dispatch and makes the process-global simd
//!     policy safe to flip mid-suite.
//! P15: ABFT checksum execution (§Rob) — with zero faults, `abft =
//!     verify` is observationally free: results are BITWISE the ABFT-off
//!     phased path's, message counts are unchanged, and every
//!     per-processor counter exceeds the baseline by exactly one
//!     integrity word (and its wire-width bytes) per sweep message —
//!     matching the ABFT-aware `expected_proc_stats` closed form — on
//!     both transports × both comm modes × both wire formats × r ∈
//!     {1, 4}; checksum construction itself charges exactly one
//!     n(n+1)/2-word allreduce per rank, reported separately. Scrub mode
//!     is equally bitwise and scrubs nothing. Under forced bit flips the
//!     system is never silently wrong: a wire flip (any bit position) is
//!     caught by the per-message integrity word or never fired — Ok
//!     means the bitwise fault-free oracle; a high-exponent-bit
//!     accumulator flip (fires every block) always trips the per-block
//!     γ-bounded checksum check — verify mode surfaces a typed
//!     `Corrupt`, scrub mode recomputes the block and returns the
//!     bitwise oracle — and a clean rerun through the same plan stays
//!     bitwise after any failure.

use sttsv::apps::{self, RecoveryPolicy};
use sttsv::coordinator::session::SolverSession;
use sttsv::coordinator::{
    run_comm_only, run_comm_only_multi, run_sttsv_opts, CommMode, ExecOpts, SttsvPlan,
};
use sttsv::partition::{classify, BlockKind, TetraPartition};
use sttsv::runtime::{packed_ternary_mults, set_simd_policy, Backend, SimdPolicy};
use sttsv::schedule::CommSchedule;
use sttsv::serve::{AdmissionPolicy, SttsvServer};
use sttsv::simulator::{
    allreduce_stats, AbftMode, CommStats, FailureReport, FaultPlan, SttsvError, TransportKind,
    WireFormat,
};
use sttsv::steiner::{spherical, sqs8};
use sttsv::tensor::{linalg, PackedBlockView, SymTensor};
use sttsv::util::proptest::check;
use sttsv::util::rng::Rng;

fn partition_pool() -> Vec<TetraPartition> {
    vec![
        TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap(),
        TetraPartition::from_steiner(&spherical(3).unwrap()).unwrap(),
        TetraPartition::from_steiner(&sqs8()).unwrap(),
    ]
}

#[test]
fn p1_distributed_equals_sequential_oracle() {
    let pool = partition_pool();
    check(
        "distributed == oracle",
        0xA11CE,
        12,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(7); // 2..=8, including non-divisible-by-λ₁
            let mode = if rng.below(2) == 0 {
                CommMode::PointToPoint
            } else {
                CommMode::AllToAll
            };
            let batch = rng.below(2) == 0;
            let packed = rng.below(2) == 0;
            let overlap = rng.below(2) == 0;
            let compiled = rng.below(2) == 0;
            let seed = rng.next_u64();
            (part_idx, b, mode, batch, packed, overlap, compiled, seed)
        },
        |&(part_idx, b, mode, batch, packed, overlap, compiled, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0x5555);
            let x = rng.normal_vec(n);
            let want = tensor.sttsv(&x);
            let rep = run_sttsv_opts(
                &tensor,
                &x,
                part,
                ExecOpts {
                    mode,
                    backend: Backend::Native,
                    batch,
                    packed,
                    overlap,
                    compiled,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                if (rep.y[i] - want[i]).abs() > 3e-3 * scale {
                    return Err(format!(
                        "mismatch at i={i}: {} vs {} (scale {scale})",
                        rep.y[i], want[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p2_comm_counters_match_closed_form_on_every_proc() {
    check(
        "comm == closed form",
        0xB0B,
        8,
        |rng: &mut Rng| {
            let q = [2usize, 3][rng.below(2)];
            let mult = 1 + rng.below(3);
            (q, mult)
        },
        |&(q, mult)| {
            let part = TetraPartition::from_steiner(&spherical(q as u64).unwrap())
                .map_err(|e| e.to_string())?;
            let lambda1 = q * (q + 1);
            let b = lambda1 * mult;
            let n = b * part.m;
            let stats = run_comm_only(&part, b, CommMode::PointToPoint)
                .map_err(|e| e.to_string())?;
            let expected = 2 * (n * (q + 1) / (q * q + 1) - n / part.p) as u64;
            for (p, s) in stats.iter().enumerate() {
                if s.sent_words != expected || s.recv_words != expected {
                    return Err(format!(
                        "proc {p}: sent {} recv {} expected {expected}",
                        s.sent_words, s.recv_words
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p3_total_ternary_mults_invariant() {
    let pool = partition_pool();
    check(
        "total ternary mults == n²(n+1)/2",
        0xC0DE,
        9,
        |rng: &mut Rng| (rng.below(pool.len()), 2 + rng.below(5), rng.next_u64()),
        |&(part_idx, b, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed);
            let x = rng.normal_vec(n);
            let rep = run_sttsv_opts(&tensor, &x, part, ExecOpts::default())
                .map_err(|e| e.to_string())?;
            let want = (n * n * (n + 1) / 2) as u64;
            if rep.total_ternary_mults() != want {
                return Err(format!(
                    "total mults {} != {want}",
                    rep.total_ternary_mults()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn p4_schedules_valid_for_all_supported_systems() {
    for sys in [spherical(2).unwrap(), spherical(3).unwrap(), spherical(4).unwrap(), sqs8()] {
        let part = TetraPartition::from_steiner(&sys).unwrap();
        let sched = CommSchedule::build(&part).unwrap();
        sched.validate(&part).unwrap();
        // model constraint re-checked here: one send + one recv per step max
        for step in &sched.steps {
            let mut s = vec![0u8; part.p];
            let mut r = vec![0u8; part.p];
            for &xi in step {
                s[sched.xfers[xi].from] += 1;
                r[sched.xfers[xi].to] += 1;
            }
            assert!(s.iter().all(|&c| c <= 1));
            assert!(r.iter().all(|&c| c <= 1));
        }
    }
}

#[test]
fn load_balance_within_paper_slack() {
    // §7.1: imbalance does not affect the leading term — max/mean ternary
    // mults stays within the diagonal-block slack.
    for q in [2usize, 3] {
        let part = TetraPartition::from_steiner(&spherical(q as u64).unwrap()).unwrap();
        let b = 8;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 3);
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(n);
        let rep = run_sttsv_opts(&tensor, &x, &part, ExecOpts::default()).unwrap();
        let max = rep.max_ternary_mults() as f64;
        let mean = rep.total_ternary_mults() as f64 / part.p as f64;
        assert!(max / mean < 1.15, "q={q}: max/mean = {}", max / mean);
    }
}

#[test]
fn p5_run_multi_equals_r_independent_oracles() {
    // The batched multi-RHS path must match r independent sequential
    // Algorithm 4 oracle calls, column by column, across partitions that
    // exercise all three block kinds (off-diagonal, non-central diagonal,
    // central diagonal), both comm modes, batched and per-block dispatch —
    // and its comm counters must be exactly r-deep-packed: words r× the
    // single-vector dry run, messages identical to it.
    let pool = partition_pool();
    check(
        "run_multi == r oracles",
        0xBA7C4,
        10,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(6); // 2..=7, including non-divisible-by-λ₁
            let r = 1 + rng.below(5); // 1..=5
            let mode = if rng.below(2) == 0 {
                CommMode::PointToPoint
            } else {
                CommMode::AllToAll
            };
            let batch = rng.below(2) == 0;
            let packed = rng.below(2) == 0;
            let overlap = rng.below(2) == 0;
            let compiled = rng.below(2) == 0;
            let seed = rng.next_u64();
            (part_idx, b, r, mode, batch, packed, overlap, compiled, seed)
        },
        |&(part_idx, b, r, mode, batch, packed, overlap, compiled, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0xAAAA);
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            let plan = SttsvPlan::new(
                &tensor,
                part,
                ExecOpts {
                    mode,
                    backend: Backend::Native,
                    batch,
                    packed,
                    overlap,
                    compiled,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            let rep = plan.run_multi(&xs).map_err(|e| e.to_string())?;
            if rep.ys.len() != r {
                return Err(format!("{} result columns, expected {r}", rep.ys.len()));
            }
            for (l, x) in xs.iter().enumerate() {
                let want = tensor.sttsv(x);
                let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                for i in 0..n {
                    if (rep.ys[l][i] - want[i]).abs() > 3e-3 * scale {
                        return Err(format!(
                            "col {l} mismatch at i={i}: {} vs {} (scale {scale})",
                            rep.ys[l][i], want[i]
                        ));
                    }
                }
            }
            // r-deep packing invariant vs the single-vector dry run
            let dry = run_comm_only(part, b, mode).map_err(|e| e.to_string())?;
            for (p, (pr, d)) in rep.per_proc.iter().zip(&dry).enumerate() {
                if pr.stats.sent_words != r as u64 * d.sent_words {
                    return Err(format!(
                        "proc {p}: sent {} words, expected r×{}",
                        pr.stats.sent_words, d.sent_words
                    ));
                }
                if pr.stats.sent_msgs != d.sent_msgs {
                    return Err(format!(
                        "proc {p}: sent {} msgs, expected {} (r-independent)",
                        pr.stats.sent_msgs, d.sent_msgs
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p6_packed_path_matches_dense_extract_on_random_partitions() {
    // The zero-copy packed plan (contract in place against the shared
    // SymTensor buffer) and the dense-extract plan must agree within 1e-4
    // column-by-column for r ∈ {1, 4} on random partitions, block sizes,
    // and comm modes — and the packed plan must hold no dense copies.
    let pool = partition_pool();
    check(
        "packed == dense-extract",
        0xBACC,
        10,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(6); // 2..=7
            let r = [1usize, 4][rng.below(2)];
            let mode = if rng.below(2) == 0 {
                CommMode::PointToPoint
            } else {
                CommMode::AllToAll
            };
            let batch = rng.below(2) == 0;
            let overlap = rng.below(2) == 0;
            let seed = rng.next_u64();
            (part_idx, b, r, mode, batch, overlap, seed)
        },
        |&(part_idx, b, r, mode, batch, overlap, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0x7777);
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            let packed_plan = SttsvPlan::new(
                &tensor,
                part,
                ExecOpts {
                    mode,
                    backend: Backend::Native,
                    batch,
                    packed: true,
                    overlap,
                    // pin the packed INTERPRETER vs dense-extract (still
                    // the PJRT fallback and the --no-compiled path);
                    // compiled-vs-interpreter is property P10
                    compiled: false,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            if packed_plan.resident_tensor_words() != 0 {
                return Err(format!(
                    "packed plan copied {} tensor words",
                    packed_plan.resident_tensor_words()
                ));
            }
            let dense_plan = SttsvPlan::new(
                &tensor,
                part,
                ExecOpts {
                    mode,
                    backend: Backend::Native,
                    batch,
                    packed: false,
                    overlap,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            let yp = packed_plan.run_multi(&xs).map_err(|e| e.to_string())?;
            let yd = dense_plan.run_multi(&xs).map_err(|e| e.to_string())?;
            for l in 0..r {
                let scale = yd.ys[l].iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                for i in 0..n {
                    if (yp.ys[l][i] - yd.ys[l][i]).abs() > 1e-4 * scale {
                        return Err(format!(
                            "col {l} i={i}: packed {} vs dense {} (scale {scale})",
                            yp.ys[l][i], yd.ys[l][i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p7_packed_executed_mults_equal_logical_accounting_per_proc() {
    // Per processor, the ternary multiplications the packed kernels
    // actually execute (packed_ternary_mults: one per unique entry per
    // output contribution, walked from the kernels' loop bounds) must equal
    // the §7.1 logical accounting the coordinator charges
    // (block_ternary_mults sums) — i.e. the packed path's executed flops
    // ARE the paper's counts, with no dense overshoot on diagonal blocks.
    for sys in [spherical(2).unwrap(), spherical(3).unwrap(), sqs8()] {
        let part = TetraPartition::from_steiner(&sys).unwrap();
        for b in [3usize, 6] {
            let n = b * part.m;
            let tensor = SymTensor::random(n, 0xBEEF);
            let mut rng = Rng::new(0xF00D);
            let x = rng.normal_vec(n);
            let plan = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
            let rep = plan.run(&x).unwrap();
            for p in 0..part.p {
                let executed: u64 = part
                    .owned_blocks(p)
                    .iter()
                    .map(|&(i, j, k)| packed_ternary_mults(&PackedBlockView::new(i, j, k, b)))
                    .sum();
                assert_eq!(
                    executed, rep.per_proc[p].ternary_mults,
                    "m={} b={b} proc {p}",
                    part.m
                );
            }
            // and the central-block check that motivated the kernels: the
            // dense sweep would execute 3b³ on every block regardless of
            // kind, overshooting wherever a diagonal block is owned.
            for p in 0..part.p {
                let has_diag = part
                    .owned_blocks(p)
                    .iter()
                    .any(|&(i, j, k)| classify(i, j, k) != BlockKind::OffDiagonal);
                let dense_would: u64 =
                    3 * (b as u64).pow(3) * part.owned_blocks(p).len() as u64;
                if has_diag {
                    assert!(
                        rep.per_proc[p].ternary_mults < dense_would,
                        "proc {p}: packed {} !< dense {}",
                        rep.per_proc[p].ternary_mults,
                        dense_would
                    );
                }
            }
        }
    }
}

#[test]
fn p8_overlap_matches_phased_and_comm_cost_is_invariant() {
    // The overlapped pipeline may reorder arrivals and interleave compute
    // with communication arbitrarily, but it must (a) agree with the
    // phased oracle within 1e-4 column-by-column for r ∈ {1, 4}, (b)
    // produce EXACTLY equal per-processor CommStats — all four counters —
    // in both PointToPoint and AllToAll, and (c) allocate zero payload
    // buffers once its plan's pools are warm.
    let pool = partition_pool();
    check(
        "overlap == phased + exact comm",
        0x0E12,
        10,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(6); // 2..=7, including non-divisible-by-λ₁
            let r = [1usize, 4][rng.below(2)];
            let mode = if rng.below(2) == 0 {
                CommMode::PointToPoint
            } else {
                CommMode::AllToAll
            };
            let seed = rng.next_u64();
            (part_idx, b, r, mode, seed)
        },
        |&(part_idx, b, r, mode, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0xE12);
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            let phased_plan = SttsvPlan::new(
                &tensor,
                part,
                ExecOpts { mode, overlap: false, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            let overlap_plan = SttsvPlan::new(
                &tensor,
                part,
                ExecOpts { mode, overlap: true, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            let ph = phased_plan.run_multi(&xs).map_err(|e| e.to_string())?;
            let ov = overlap_plan.run_multi(&xs).map_err(|e| e.to_string())?;
            for l in 0..r {
                let scale = ph.ys[l].iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                for i in 0..n {
                    if (ov.ys[l][i] - ph.ys[l][i]).abs() > 1e-4 * scale {
                        return Err(format!(
                            "col {l} i={i}: overlap {} vs phased {} (scale {scale})",
                            ov.ys[l][i], ph.ys[l][i]
                        ));
                    }
                }
            }
            for p in 0..part.p {
                let (a, o) = (&ph.per_proc[p].stats, &ov.per_proc[p].stats);
                if a != o {
                    return Err(format!(
                        "proc {p}: phased {a:?} != overlap {o:?} (model cost \
                         must be invariant)"
                    ));
                }
            }
            // steady state: the warmed plan re-runs without allocating
            let again = overlap_plan.run_multi(&xs).map_err(|e| e.to_string())?;
            if again.fresh_payload_allocs != 0 {
                return Err(format!(
                    "warm overlap run allocated {} payload buffers",
                    again.fresh_payload_allocs
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn p9_resident_power_session_equals_k_host_runs() {
    // A k-iteration resident session must reproduce, within f32
    // reassociation tolerance, exactly what k independent plan.run calls
    // plus host scalar arithmetic produce — and its comm must be exactly
    // k × (one phased STTSV + the collective closed form), per processor,
    // in both comm modes.
    for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 0x911);
        let mut rng = Rng::new(0x912);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        let k = 6usize;
        let plan =
            SttsvPlan::new(&tensor, &part, ExecOpts { mode, ..Default::default() }).unwrap();
        // tol = 0 pins the session to exactly k iterations.
        let solve = SolverSession::new(&plan).power_method(&x0, k, 0.0).unwrap();
        assert_eq!(solve.iters.len(), k, "{mode:?}");
        assert_eq!(solve.worker_spawns, part.p, "{mode:?}");

        // Host-centric replica: k independent plan.run calls.
        let mut x = x0.clone();
        linalg::normalize(&mut x);
        for t in 0..k {
            let rep = plan.run(&x).unwrap();
            let mut y = rep.y;
            let lambda = linalg::dot(&x, &y);
            let norm = linalg::normalize(&mut y);
            let delta = x
                .iter()
                .zip(&y)
                .map(|(a, b)| {
                    let d = a - b;
                    (d * d) as f64
                })
                .sum::<f64>()
                .sqrt() as f32;
            let it = &solve.iters[t];
            assert!(
                (it.lambda - lambda).abs() < 1e-4 * lambda.abs().max(1.0),
                "{mode:?} iter {t}: lambda {} vs host {lambda}",
                it.lambda
            );
            assert!(
                (it.norm - norm).abs() < 1e-4 * norm.abs().max(1.0),
                "{mode:?} iter {t}: norm {} vs host {norm}",
                it.norm
            );
            assert!(
                (it.delta - delta).abs() < 1e-4,
                "{mode:?} iter {t}: delta {} vs host {delta}",
                it.delta
            );
            x = y;
        }
        for i in 0..n {
            assert!(
                (solve.x[i] - x[i]).abs() < 1e-4,
                "{mode:?} x[{i}]: resident {} vs host {}",
                solve.x[i],
                x[i]
            );
        }

        // Comm: session totals == k × (phased STTSV dry run + collectives).
        let dry = run_comm_only(&part, b, mode).unwrap();
        for p in 0..part.p {
            let mut per_iter = dry[p];
            per_iter.absorb(&allreduce_stats(part.p, p, 2));
            per_iter.absorb(&allreduce_stats(part.p, p, 1));
            let mut want = CommStats::default();
            for _ in 0..k {
                want.absorb(&per_iter);
            }
            assert_eq!(
                solve.per_proc[p].stats, want,
                "{mode:?} proc {p}: session comm != k × (STTSV + collectives)"
            );
        }
    }
}

#[test]
fn p9_resident_cp_session_equals_k_host_multi_runs() {
    // The r = 4 instance: a k-sweep resident CP session vs k independent
    // plan.run_multi calls + host Gram/gradient arithmetic — values within
    // 1e-4, comm exactly k × (one r-deep STTSV + r²-word and 1-word
    // allreduces), in both comm modes.
    for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 4usize;
        let n = b * part.m;
        let r = 4usize;
        let tensor = SymTensor::random(n, 0x921);
        let mut rng = Rng::new(0x922);
        // Small columns keep ‖XᵀX‖ modest so the fixed step is stable over
        // the k sweeps (the test pins session == host equality, not
        // convergence).
        let x0: Vec<Vec<f32>> = (0..r)
            .map(|_| rng.normal_vec(n).iter().map(|v| 0.3 * v).collect())
            .collect();
        let k = 4usize;
        let step = 0.01f32;
        let plan =
            SttsvPlan::new(&tensor, &part, ExecOpts { mode, ..Default::default() }).unwrap();
        let solve = SolverSession::new(&plan).cp_sweeps(&x0, k, step, 0.0).unwrap();
        assert_eq!(solve.iters.len(), k, "{mode:?}");
        assert_eq!(solve.worker_spawns, part.p, "{mode:?}");

        // Host replica.
        let mut x = x0.clone();
        let mut last_grad: Vec<Vec<f32>> = Vec::new();
        for t in 0..k {
            let rep = plan.run_multi(&x).unwrap();
            let mut gram = vec![0.0f32; r * r];
            for a in 0..r {
                for l in 0..r {
                    let d = linalg::dot(&x[a], &x[l]);
                    gram[a * r + l] = d * d;
                }
            }
            let mut gn2 = 0.0f64;
            let mut grad = vec![vec![0.0f32; n]; r];
            for l in 0..r {
                for i in 0..n {
                    let mut v = 0.0f32;
                    for a in 0..r {
                        v += x[a][i] * gram[a * r + l];
                    }
                    let g = v - rep.ys[l][i];
                    grad[l][i] = g;
                    gn2 += (g as f64) * (g as f64);
                }
            }
            for l in 0..r {
                for i in 0..n {
                    x[l][i] -= step * grad[l][i];
                }
            }
            let gnorm = gn2.sqrt() as f32;
            let it = &solve.iters[t];
            assert!(
                (it.gnorm - gnorm).abs() < 1e-4 * gnorm.abs().max(1.0),
                "{mode:?} sweep {t}: gnorm {} vs host {gnorm}",
                it.gnorm
            );
            last_grad = grad;
        }
        for l in 0..r {
            let scale = x[l].iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!(
                    (solve.x_cols[l][i] - x[l][i]).abs() < 1e-4 * scale,
                    "{mode:?} x[{l}][{i}]: resident {} vs host {}",
                    solve.x_cols[l][i],
                    x[l][i]
                );
                assert!(
                    (solve.grad_cols[l][i] - last_grad[l][i]).abs() < 1e-3 * scale.max(10.0),
                    "{mode:?} grad[{l}][{i}]: resident {} vs host {}",
                    solve.grad_cols[l][i],
                    last_grad[l][i]
                );
            }
        }

        // Comm: totals == k × (r-deep STTSV dry run + collectives).
        let dry = run_comm_only_multi(&part, b, mode, r).unwrap();
        for p in 0..part.p {
            let mut per_iter = dry[p];
            per_iter.absorb(&allreduce_stats(part.p, p, r * r));
            per_iter.absorb(&allreduce_stats(part.p, p, 1));
            let mut want = CommStats::default();
            for _ in 0..k {
                want.absorb(&per_iter);
            }
            assert_eq!(
                solve.per_proc[p].stats, want,
                "{mode:?} proc {p}: session comm != k × (r-deep STTSV + collectives)"
            );
        }
    }
}

#[test]
fn p9_collectives_match_recursive_doubling_closed_form() {
    // Integration-level twin of the simulator unit test, at the partition
    // sizes the sessions actually use (P = 4, 10, 14, 30): measured
    // allreduce counters == allreduce_stats for the session widths.
    use sttsv::simulator;
    for p in [4usize, 10, 14, 30] {
        for width in [1usize, 2, 16] {
            let out = simulator::run(p, |comm| {
                let mut buf = vec![comm.rank as f32 + 0.5; width];
                comm.allreduce_sum(&mut buf)?;
                Ok((buf, comm.stats))
            })
            .unwrap();
            let want: f32 = (0..p).map(|r| r as f32 + 0.5).sum();
            for (rank, (buf, stats)) in out.iter().enumerate() {
                assert!(buf.iter().all(|&v| (v - want).abs() < 1e-2 * want),
                    "p={p} width={width} rank={rank}");
                assert_eq!(buf, &out[0].0, "p={p} rank {rank}: not bitwise identical");
                assert_eq!(*stats, allreduce_stats(p, rank, width), "p={p} rank {rank}");
            }
        }
    }
}

#[test]
fn p10_compiled_programs_match_packed_interpreter() {
    // The compiled sweep-program path must be a pure execution-strategy
    // change: identical results within f32 reassociation tolerance on any
    // path, BITWISE identical on the deterministic phased path at
    // compute_threads = 1, and exactly invariant per-processor words,
    // messages, and charged ternary mults — r ∈ {1, 4}, both comm modes,
    // phased and overlap, random partitions and block sizes.
    let pool = partition_pool();
    check(
        "compiled == interpreted",
        0x0F10,
        10,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(6); // 2..=7, including non-divisible-by-λ₁
            let r = [1usize, 4][rng.below(2)];
            let mode = if rng.below(2) == 0 {
                CommMode::PointToPoint
            } else {
                CommMode::AllToAll
            };
            let overlap = rng.below(2) == 0;
            let seed = rng.next_u64();
            (part_idx, b, r, mode, overlap, seed)
        },
        |&(part_idx, b, r, mode, overlap, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0xF10);
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            let compiled_opts = ExecOpts { mode, overlap, ..Default::default() };
            let compiled_plan =
                SttsvPlan::new(&tensor, part, compiled_opts).map_err(|e| e.to_string())?;
            if compiled_plan.sweep_program_builds() != part.p as u64 {
                return Err(format!(
                    "{} programs built, expected P = {}",
                    compiled_plan.sweep_program_builds(),
                    part.p
                ));
            }
            if compiled_plan.resident_tensor_words() != 0 {
                return Err("compiled plan holds resident tensor words".into());
            }
            let interp_plan = SttsvPlan::new(
                &tensor,
                part,
                ExecOpts { mode, overlap, compiled: false, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            let rc = compiled_plan.run_multi(&xs).map_err(|e| e.to_string())?;
            let ri = interp_plan.run_multi(&xs).map_err(|e| e.to_string())?;
            for l in 0..r {
                let scale = ri.ys[l].iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                for i in 0..n {
                    if !overlap && rc.ys[l][i].to_bits() != ri.ys[l][i].to_bits() {
                        return Err(format!(
                            "phased col {l} i={i}: compiled {} != interpreted {} bitwise",
                            rc.ys[l][i], ri.ys[l][i]
                        ));
                    }
                    if (rc.ys[l][i] - ri.ys[l][i]).abs() > 1e-4 * scale {
                        return Err(format!(
                            "col {l} i={i}: compiled {} vs interpreted {} (scale {scale})",
                            rc.ys[l][i], ri.ys[l][i]
                        ));
                    }
                }
            }
            for p in 0..part.p {
                let (c, i) = (&rc.per_proc[p], &ri.per_proc[p]);
                if c.stats != i.stats {
                    return Err(format!(
                        "proc {p}: compiled comm {:?} != interpreted {:?}",
                        c.stats, i.stats
                    ));
                }
                if c.ternary_mults != i.ternary_mults {
                    return Err(format!(
                        "proc {p}: compiled charged {} mults, interpreted {}",
                        c.ternary_mults, i.ternary_mults
                    ));
                }
            }
            // The 4-thread intra-worker pool: results within tolerance,
            // not a single comm counter or charged mult moved.
            let pool_plan = SttsvPlan::new(
                &tensor,
                part,
                ExecOpts { mode, overlap, compute_threads: 4, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            let rp = pool_plan.run_multi(&xs).map_err(|e| e.to_string())?;
            for p in 0..part.p {
                if rp.per_proc[p].stats != ri.per_proc[p].stats {
                    return Err(format!("proc {p}: compute pool changed CommStats"));
                }
                if rp.per_proc[p].ternary_mults != ri.per_proc[p].ternary_mults {
                    return Err(format!("proc {p}: compute pool changed charged mults"));
                }
            }
            for l in 0..r {
                let scale = ri.ys[l].iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                for i in 0..n {
                    if (rp.ys[l][i] - ri.ys[l][i]).abs() > 1e-4 * scale {
                        return Err(format!(
                            "pool col {l} i={i}: {} vs {} (scale {scale})",
                            rp.ys[l][i], ri.ys[l][i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p8_nonblocking_comm_dry_run_matches_blocking_counters() {
    // Comm-only exercise of the nonblocking API (no tensor, no compute):
    // replay the Theorem 6 phase-1 transfer set once through
    // isend/try_recv/recv_into and once through the blocking send/recv,
    // and require identical per-processor counters. Payload sizes are the
    // real portion sizes, so this doubles as a dry run of the overlap
    // pipeline's message layout.
    use sttsv::simulator::{self, BufPool};
    use std::sync::Mutex;
    for q in [2u64, 3] {
        let part = TetraPartition::from_steiner(&spherical(q).unwrap()).unwrap();
        let sched = CommSchedule::build(&part).unwrap();
        let b = 7usize; // uneven portions
        let xfers = &sched.xfers;
        let blocking = simulator::run(part.p, |comm| {
            let me = comm.rank;
            for (xi, xf) in xfers.iter().enumerate() {
                if xf.from == me {
                    comm.send(xf.to, xi as u64, vec![0.5; xf.words(&part, b)])?;
                }
            }
            for (xi, xf) in xfers.iter().enumerate() {
                if xf.to == me {
                    comm.recv(xf.from, xi as u64)?;
                }
            }
            Ok(comm.stats)
        })
        .unwrap();
        let pools: Vec<Mutex<BufPool>> =
            (0..part.p).map(|_| Mutex::new(BufPool::new())).collect();
        let (nonblocking, metrics) = simulator::run_ext(part.p, Some(&pools), |comm| {
            let me = comm.rank;
            let payload = vec![0.5f32; b]; // max portion size
            let mut expected = 0usize;
            for (xi, xf) in xfers.iter().enumerate() {
                if xf.from == me {
                    comm.isend(xf.to, xi as u64, &payload[..xf.words(&part, b)])?;
                }
                if xf.to == me {
                    expected += 1;
                }
            }
            let mut scratch = vec![0.0f32; b];
            while expected > 0 {
                let (from, tag) = match comm.try_recv() {
                    Some(key) => key,
                    None => comm.recv_any()?,
                };
                let words = xfers[tag as usize].words(&part, b);
                comm.recv_into(from, tag, &mut scratch[..words])?;
                expected -= 1;
            }
            Ok(comm.stats)
        })
        .unwrap();
        assert_eq!(blocking, nonblocking, "q={q}");
        assert!(metrics.peak_inflight_words > 0, "q={q}");
    }
}

#[test]
fn p11_spsc_transport_matches_mpsc_oracle_exactly() {
    // The SPSC rings are a *transport*, not a different algorithm: every
    // counter the α-β-γ model prices must be bitwise identical to the mpsc
    // oracle's, per processor, in every execution mode. The phased path
    // must additionally produce bitwise-identical result vectors (its
    // arrival order is protocol-determined); overlap accumulates phase-3
    // partials in arrival order, so values there agree only up to f32
    // reassociation.
    let pool = partition_pool();
    check(
        "spsc == mpsc oracle",
        0x0511,
        6,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(5); // 2..=6, including non-divisible-by-λ₁
            let seed = rng.next_u64();
            (part_idx, b, seed)
        },
        |&(part_idx, b, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0x511);
            let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(n)).collect();
            for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
                for overlap in [false, true] {
                    for r in [1usize, 4] {
                        let xs = &xs[..r];
                        let plan_for = |transport| {
                            SttsvPlan::new(
                                &tensor,
                                part,
                                ExecOpts { mode, overlap, transport, ..Default::default() },
                            )
                        };
                        let mp = plan_for(TransportKind::Mpsc)
                            .map_err(|e| e.to_string())?
                            .run_multi(xs)
                            .map_err(|e| e.to_string())?;
                        let sp = plan_for(TransportKind::Spsc)
                            .map_err(|e| e.to_string())?
                            .run_multi(xs)
                            .map_err(|e| e.to_string())?;
                        let ctx = format!("{mode:?} overlap={overlap} r={r}");
                        for p in 0..part.p {
                            let (m, s) = (&mp.per_proc[p], &sp.per_proc[p]);
                            if m.stats != s.stats {
                                return Err(format!(
                                    "{ctx} proc {p}: mpsc {:?} != spsc {:?}",
                                    m.stats, s.stats
                                ));
                            }
                            if m.ternary_mults != s.ternary_mults {
                                return Err(format!(
                                    "{ctx} proc {p}: mults {} != {}",
                                    m.ternary_mults, s.ternary_mults
                                ));
                            }
                        }
                        for l in 0..r {
                            if overlap {
                                let scale = mp.ys[l]
                                    .iter()
                                    .map(|v| v.abs())
                                    .fold(1.0f32, f32::max);
                                for i in 0..n {
                                    if (sp.ys[l][i] - mp.ys[l][i]).abs() > 2e-4 * scale {
                                        return Err(format!(
                                            "{ctx} col {l} i={i}: spsc {} vs mpsc {}",
                                            sp.ys[l][i], mp.ys[l][i]
                                        ));
                                    }
                                }
                            } else if sp.ys[l] != mp.ys[l] {
                                return Err(format!(
                                    "{ctx} col {l}: phased results must be bitwise \
                                     transport-invariant"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p12_coalesced_serving_matches_serial_and_bills_exact_comm() {
    // The serving layer must ADD nothing to the numerics and MOVE nothing
    // in the comm model: coalescing is exactly `run_multi`, attribution is
    // exactly the closed form, and the plan cache builds once. Depths 3
    // and 5 route through the dynamic-width compiled microkernel fallback,
    // 2/4/8 through the register tiles — same contract either way.
    let pool = partition_pool();
    check(
        "serve coalescing == serial",
        0x5E12,
        6,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(4); // 2..=5, including non-divisible-by-λ₁
            let depth = [2usize, 3, 4, 5, 8][rng.below(5)];
            let overlap = rng.below(2) == 0;
            let seed = rng.next_u64();
            (part_idx, b, depth, overlap, seed)
        },
        |&(part_idx, b, depth, overlap, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0x5E12);
            let nq = 2 * depth;
            let xs: Vec<Vec<f32>> = (0..nq).map(|_| rng.normal_vec(n)).collect();
            let opts = ExecOpts { overlap, ..Default::default() };
            let server = SttsvServer::new(
                &tensor,
                part,
                opts,
                AdmissionPolicy::coalescing(1.0, depth),
                2,
            )
            .map_err(|e| e.to_string())?;
            for (k, x) in xs.iter().enumerate() {
                // One tight burst: everything lands inside the window, so
                // admission packs exactly two full depth-r batches.
                server
                    .submit(x.clone(), 1e-4 * k as f64)
                    .map_err(|e| e.to_string())?;
            }
            let rep = server.drain().map_err(|e| e.to_string())?;
            if rep.batches.len() != 2 || rep.batches.iter().any(|bt| bt.r != depth) {
                return Err(format!(
                    "expected 2 batches of depth {depth}, got {:?}",
                    rep.batches.iter().map(|bt| bt.r).collect::<Vec<_>>()
                ));
            }
            // drain() already asserted per-batch counters equal
            // `expected_proc_stats(depth)`; independently pin the r-scaling
            // law against the SINGLE-query closed form: words exactly r×,
            // messages unchanged, on every processor of every batch.
            let plan = server.plan().map_err(|e| e.to_string())?;
            let single = plan.expected_proc_stats(1);
            for (bi, bt) in rep.batches.iter().enumerate() {
                for (p, (got, one)) in bt.per_proc.iter().zip(&single).enumerate() {
                    if got.sent_words != depth as u64 * one.sent_words
                        || got.recv_words != depth as u64 * one.recv_words
                        || got.sent_msgs != one.sent_msgs
                        || got.recv_msgs != one.recv_msgs
                    {
                        return Err(format!(
                            "batch {bi} proc {p}: {got:?} is not one {depth}-deep \
                             STTSV (1-deep form {one:?})"
                        ));
                    }
                }
                // And the per-query bill inverts it exactly.
                let busiest = bt
                    .per_proc
                    .iter()
                    .copied()
                    .max_by_key(|s| s.total_words())
                    .unwrap();
                let one_busiest = single
                    .iter()
                    .copied()
                    .max_by_key(|s| s.total_words())
                    .unwrap();
                let share = busiest.per_query(depth);
                if share.sent_words != one_busiest.sent_words
                    || share.recv_words != one_busiest.recv_words
                {
                    return Err(format!(
                        "batch {bi}: per-query words {share:?} != single-query \
                         bill {one_busiest:?}"
                    ));
                }
            }
            // Bitwise: the demultiplexed outcomes ARE the same-depth
            // batched oracle's columns (phased path; overlap accumulates
            // phase-3 partials in arrival order, so bitwise claims stop at
            // the P11 boundary there).
            if !overlap {
                for (g, group) in xs.chunks(depth).enumerate() {
                    let oracle = plan.run_multi(group).map_err(|e| e.to_string())?;
                    for (l, want) in oracle.ys.iter().enumerate() {
                        if rep.outcomes[depth * g + l].y != *want {
                            return Err(format!(
                                "batch {g} col {l}: coalesced result is not \
                                 bitwise the run_multi oracle"
                            ));
                        }
                    }
                }
            }
            // Tolerance vs N serial plan.run calls, phased AND overlap
            // (cross-depth bitwise equality is impossible: the scalar and
            // fused-multi kernel families group central tail adds
            // differently — P10's documented boundary).
            let mut serial_ys: Vec<Vec<f32>> = Vec::with_capacity(nq);
            for x in &xs {
                serial_ys.push(plan.run(x).map_err(|e| e.to_string())?.y);
            }
            for o in &rep.outcomes {
                let want = &serial_ys[o.id as usize];
                let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                for i in 0..n {
                    if (o.y[i] - want[i]).abs() > 1e-4 * scale {
                        return Err(format!(
                            "query {} i={i}: coalesced {} vs serial {}",
                            o.id, o.y[i], want[i]
                        ));
                    }
                }
            }
            // The serial admission policy takes the identical r = 1 code
            // path plan.run takes: bitwise on the phased path.
            if !overlap {
                let sserver =
                    SttsvServer::new(&tensor, part, opts, AdmissionPolicy::serial(), 2)
                        .map_err(|e| e.to_string())?;
                for (k, x) in xs.iter().enumerate() {
                    sserver
                        .submit(x.clone(), k as f64)
                        .map_err(|e| e.to_string())?;
                }
                let srep = sserver.drain().map_err(|e| e.to_string())?;
                for o in &srep.outcomes {
                    if o.batch_r != 1 || o.y != serial_ys[o.id as usize] {
                        return Err(format!(
                            "query {}: serial-policy serving must be bitwise \
                             plan.run",
                            o.id
                        ));
                    }
                }
            }
            // Cache warmup: one build served everything above; a second
            // drain through the same server builds nothing new.
            let c = server.cache_counters();
            if c.plan_builds != 1 {
                return Err(format!("plan_builds {} != 1 after warmup", c.plan_builds));
            }
            for (k, x) in xs.iter().take(depth).enumerate() {
                server
                    .submit(x.clone(), 100.0 + 1e-4 * k as f64)
                    .map_err(|e| e.to_string())?;
            }
            server.drain().map_err(|e| e.to_string())?;
            let c2 = server.cache_counters();
            if c2.plan_builds != c.plan_builds {
                return Err(format!(
                    "plan_builds moved {} -> {} on a warm cache",
                    c.plan_builds, c2.plan_builds
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn p13_chaos_soak_terminates_with_oracle_results_or_typed_failures() {
    // The §Rob termination contract: under seeded fault injection every
    // run either completes with oracle-equal results or unwinds into a
    // typed FailureReport — and the plan (pools, schedule, compiled
    // programs) survives the failure for a clean rerun. 32 seeds, each
    // swept over {p2p, a2a} × {phased, overlap}; every fourth seed
    // injects a deterministic rank crash instead of random transients.
    let pool = partition_pool();
    check(
        "chaos soak: typed failure or oracle result",
        0xC4A05,
        32,
        |rng: &mut Rng| {
            // P=10 and P=14 partitions keep 384 simulator runs cheap.
            let part_idx = [0usize, 2][rng.below(2)];
            let b = 2 + rng.below(3); // 2..=4
            let r = [1usize, 2][rng.below(2)];
            let rate_ppm = [500u32, 2_000, 8_000][rng.below(3)];
            let crash = rng.below(4) == 0;
            let crash_rank = rng.below(10);
            let crash_at = rng.below(40) as u64;
            let seed = rng.next_u64();
            (part_idx, b, r, rate_ppm, crash, crash_rank, crash_at, seed)
        },
        |&(part_idx, b, r, rate_ppm, crash, crash_rank, crash_at, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0xC4A0);
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            let chaos = if crash {
                FaultPlan::crash(seed, crash_rank, crash_at)
            } else {
                FaultPlan { seed, rate_ppm, crash_rank: None, crash_at: 0 }
            };
            for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
                for overlap in [false, true] {
                    let ctx = format!("{mode:?} overlap={overlap} r={r} {chaos:?}");
                    let opts = ExecOpts { mode, overlap, ..Default::default() };
                    let plan =
                        SttsvPlan::new(&tensor, part, opts).map_err(|e| e.to_string())?;
                    let oracle = plan
                        .run_multi_with(&xs, FaultPlan::default())
                        .map_err(|e| e.to_string())?;
                    match plan.run_multi_with(&xs, chaos) {
                        Ok(rep) => {
                            // Whatever fired was delay-only: the answer and
                            // the bill must be exactly the fault-free run's
                            // (bitwise phased; reassociation tolerance under
                            // overlap — the P11 boundary).
                            for p in 0..part.p {
                                if rep.per_proc[p].stats != oracle.per_proc[p].stats {
                                    return Err(format!(
                                        "{ctx} proc {p}: chaos Ok run billed {:?}, \
                                         oracle {:?}",
                                        rep.per_proc[p].stats, oracle.per_proc[p].stats
                                    ));
                                }
                            }
                            for l in 0..r {
                                if overlap {
                                    let scale = oracle.ys[l]
                                        .iter()
                                        .map(|v| v.abs())
                                        .fold(1.0f32, f32::max);
                                    for i in 0..n {
                                        if (rep.ys[l][i] - oracle.ys[l][i]).abs()
                                            > 2e-4 * scale
                                        {
                                            return Err(format!(
                                                "{ctx} col {l} i={i}: {} vs oracle {}",
                                                rep.ys[l][i], oracle.ys[l][i]
                                            ));
                                        }
                                    }
                                } else if rep.ys[l] != oracle.ys[l] {
                                    return Err(format!(
                                        "{ctx} col {l}: delay-only chaos must be \
                                         bitwise on the phased path"
                                    ));
                                }
                            }
                        }
                        Err(e) => {
                            let report = match e.downcast_ref::<FailureReport>() {
                                Some(rp) => rp,
                                None => {
                                    return Err(format!(
                                        "{ctx}: untyped failure {e:#} (no \
                                         FailureReport in the chain)"
                                    ))
                                }
                            };
                            if report.failed_rank >= part.p {
                                return Err(format!(
                                    "{ctx}: report names rank {} of {}",
                                    report.failed_rank, part.p
                                ));
                            }
                            if crash && report.failed_rank != crash_rank {
                                return Err(format!(
                                    "{ctx}: crash plan killed rank {crash_rank} \
                                     but the report blames {}",
                                    report.failed_rank
                                ));
                            }
                        }
                    }
                    // Poison survival: the SAME plan must now complete a
                    // zero-fault rerun bitwise (phased) / in tolerance
                    // (overlap) — buffers and pools recovered.
                    let clean = plan
                        .run_multi_with(&xs, FaultPlan::default())
                        .map_err(|e| format!("{ctx}: clean rerun failed: {e:#}"))?;
                    for l in 0..r {
                        if overlap {
                            let scale = oracle.ys[l]
                                .iter()
                                .map(|v| v.abs())
                                .fold(1.0f32, f32::max);
                            for i in 0..n {
                                if (clean.ys[l][i] - oracle.ys[l][i]).abs() > 2e-4 * scale
                                {
                                    return Err(format!(
                                        "{ctx} col {l} i={i}: post-failure rerun {} vs \
                                         oracle {}",
                                        clean.ys[l][i], oracle.ys[l][i]
                                    ));
                                }
                            }
                        } else if clean.ys[l] != oracle.ys[l] {
                            return Err(format!(
                                "{ctx} col {l}: post-failure rerun is not bitwise \
                                 the oracle — the plan did not survive"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p13_zero_fault_chaos_wrapper_is_observationally_invisible() {
    // A non-default plan with zero rate and no crash installs the
    // ChaosTransport decorator on every rank but must change NOTHING:
    // bitwise results (phased), tolerance-equal results (overlap), and
    // identical per-proc CommStats — on both transports and both modes.
    let pool = partition_pool();
    check(
        "zero-fault chaos == no chaos",
        0x2E40F,
        6,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(3); // 2..=4
            let r = [1usize, 2][rng.below(2)];
            let seed = rng.next_u64();
            (part_idx, b, r, seed)
        },
        |&(part_idx, b, r, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0x2E40);
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            let zero = FaultPlan::rate(seed | 1, 0.0);
            assert!(zero.is_zero() && zero != FaultPlan::default());
            for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
                for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
                    for overlap in [false, true] {
                        let ctx = format!("{transport:?} {mode:?} overlap={overlap} r={r}");
                        let opts =
                            ExecOpts { mode, overlap, transport, ..Default::default() };
                        let plan =
                            SttsvPlan::new(&tensor, part, opts).map_err(|e| e.to_string())?;
                        let plain = plan
                            .run_multi_with(&xs, FaultPlan::default())
                            .map_err(|e| e.to_string())?;
                        let wrapped = plan
                            .run_multi_with(&xs, zero)
                            .map_err(|e| e.to_string())?;
                        for p in 0..part.p {
                            if plain.per_proc[p].stats != wrapped.per_proc[p].stats {
                                return Err(format!(
                                    "{ctx} proc {p}: wrapper changed the bill: {:?} \
                                     vs {:?}",
                                    wrapped.per_proc[p].stats, plain.per_proc[p].stats
                                ));
                            }
                        }
                        for l in 0..r {
                            if overlap {
                                let scale = plain.ys[l]
                                    .iter()
                                    .map(|v| v.abs())
                                    .fold(1.0f32, f32::max);
                                for i in 0..n {
                                    if (wrapped.ys[l][i] - plain.ys[l][i]).abs()
                                        > 2e-4 * scale
                                    {
                                        return Err(format!(
                                            "{ctx} col {l} i={i}: wrapped {} vs plain {}",
                                            wrapped.ys[l][i], plain.ys[l][i]
                                        ));
                                    }
                                }
                            } else if wrapped.ys[l] != plain.ys[l] {
                                return Err(format!(
                                    "{ctx} col {l}: zero-fault wrapper must be \
                                     bitwise invisible on the phased path"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p13_crashed_sessions_recover_bitwise_or_report_without_recovery() {
    // Resident solves under a rank crash: WITH a checkpointed
    // RecoveryPolicy the reseeded restart reproduces the fault-free
    // answer bitwise; WITHOUT one, a crash that fires early surfaces the
    // typed FailureReport (never a hang, never a panic).
    let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
    check(
        "session recovery == oracle",
        0x13EC0,
        6,
        |rng: &mut Rng| {
            let b = 2 + rng.below(3); // 2..=4
            let rank = rng.below(10);
            let at = rng.below(80) as u64;
            let seed = rng.next_u64();
            (b, rank, at, seed)
        },
        |&(b, rank, at, seed)| {
            let n = b * part.m;
            let (tensor, cols) = SymTensor::odeco(n, &[3.0, 1.5], seed);
            let mut rng = Rng::new(seed ^ 0x13EC);
            let mut x0 = cols[0].clone();
            for v in x0.iter_mut() {
                *v += 0.2 * rng.normal_f32();
            }
            let opts = ExecOpts::default();
            let oracle = apps::power_method(&tensor, &part, &x0, 6, 0.0, opts)
                .map_err(|e| e.to_string())?;
            let mut chaos_opts = opts;
            chaos_opts.chaos = FaultPlan::crash(seed, rank, at);
            let policy = RecoveryPolicy {
                checkpoint_every: 2,
                max_retries: 3,
                ..RecoveryPolicy::default()
            };
            let rep =
                apps::power_method_recovering(&tensor, &part, &x0, 6, 0.0, chaos_opts, policy)
                    .map_err(|e| format!("recovering solve failed: {e:#}"))?;
            if rep.x != oracle.x {
                return Err(format!(
                    "crash({rank}@{at}): recovered x is not bitwise the fault-free \
                     solve (attempts {})",
                    rep.recovery.attempts
                ));
            }
            for (t, (got, want)) in rep.iters.iter().zip(&oracle.iters).enumerate() {
                if (got.norm, got.lambda, got.delta) != (want.norm, want.lambda, want.delta)
                {
                    return Err(format!(
                        "crash({rank}@{at}) iter {t}: scalars diverged from the \
                         fault-free solve"
                    ));
                }
            }
            // An early crash with recovery OFF must unwind into the typed
            // report (6 iterations issue far more than 16 transport ops).
            if at < 16 {
                match apps::power_method(&tensor, &part, &x0, 6, 0.0, chaos_opts) {
                    Ok(_) => {
                        return Err(format!(
                            "crash({rank}@{at}): unrecovered solve should have failed"
                        ))
                    }
                    Err(e) => {
                        let report = match e.downcast_ref::<FailureReport>() {
                            Some(rp) => rp,
                            None => {
                                return Err(format!(
                                    "crash({rank}@{at}): untyped failure {e:#}"
                                ))
                            }
                        };
                        if report.failed_rank != rank {
                            return Err(format!(
                                "crash({rank}@{at}): report blames rank {}",
                                report.failed_rank
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p14_bf16_wire_halves_bytes_at_bitwise_words_within_bf16_error() {
    // The wire format is an encoding, not an algorithm change: under
    // wire = bf16 every sweep payload travels at 2 bytes/word instead of
    // 4, so per-processor words and messages must be BITWISE those of the
    // f32 wire while sent/recv bytes are EXACTLY halved — and both runs'
    // counters must equal their plan's wire-aware
    // `expected_proc_stats(r)` closed form. Values agree with the f32
    // phased oracle within 2⁻⁷ of the column scale: each payload word
    // crosses the wire O(1) times at ≤ 2⁻⁸ relative rounding per
    // crossing (round-to-nearest-even truncation to the upper 16 bits).
    let pool = partition_pool();
    check(
        "bf16 wire: half the bytes, same words",
        0x14BF,
        4,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(4); // 2..=5, including non-divisible-by-λ₁
            let seed = rng.next_u64();
            (part_idx, b, seed)
        },
        |&(part_idx, b, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0x14BF);
            let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(n)).collect();
            for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
                for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
                    for r in [1usize, 4] {
                        let xs = &xs[..r];
                        let plan_for = |wire| {
                            SttsvPlan::new(
                                &tensor,
                                part,
                                ExecOpts {
                                    mode,
                                    transport,
                                    wire,
                                    overlap: false,
                                    ..Default::default()
                                },
                            )
                        };
                        let fplan = plan_for(WireFormat::F32).map_err(|e| e.to_string())?;
                        let f = fplan.run_multi(xs).map_err(|e| e.to_string())?;
                        let hplan = plan_for(WireFormat::Bf16).map_err(|e| e.to_string())?;
                        let h = hplan.run_multi(xs).map_err(|e| e.to_string())?;
                        let fx = fplan.expected_proc_stats(r);
                        let hx = hplan.expected_proc_stats(r);
                        let ctx = format!("{transport:?} {mode:?} r={r}");
                        for p in 0..part.p {
                            let (fs, hs) = (&f.per_proc[p].stats, &h.per_proc[p].stats);
                            if (fs.sent_words, fs.recv_words, fs.sent_msgs, fs.recv_msgs)
                                != (hs.sent_words, hs.recv_words, hs.sent_msgs, hs.recv_msgs)
                            {
                                return Err(format!(
                                    "{ctx} proc {p}: words/messages must be \
                                     wire-invariant (f32 {fs:?} vs bf16 {hs:?})"
                                ));
                            }
                            if fs.sent_bytes != 4 * fs.sent_words
                                || fs.recv_bytes != 4 * fs.recv_words
                                || hs.sent_bytes != 2 * hs.sent_words
                                || hs.recv_bytes != 2 * hs.recv_words
                            {
                                return Err(format!(
                                    "{ctx} proc {p}: bytes are not wire-width × \
                                     words (f32 {fs:?}, bf16 {hs:?})"
                                ));
                            }
                            if 2 * hs.sent_bytes != fs.sent_bytes
                                || 2 * hs.recv_bytes != fs.recv_bytes
                            {
                                return Err(format!(
                                    "{ctx} proc {p}: bf16 payload bytes are not \
                                     exactly half the f32 wire's"
                                ));
                            }
                            if *fs != fx[p] || *hs != hx[p] {
                                return Err(format!(
                                    "{ctx} proc {p}: measured counters diverge from \
                                     the wire-aware closed form"
                                ));
                            }
                        }
                        for l in 0..r {
                            let scale =
                                f.ys[l].iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                            for i in 0..n {
                                let err = (h.ys[l][i] - f.ys[l][i]).abs();
                                if err > scale / 128.0 {
                                    return Err(format!(
                                        "{ctx} col {l} i={i}: bf16 {} vs f32 {} \
                                         (err {err:.3e} > 2^-7 of scale {scale:.3e})",
                                        h.ys[l][i], f.ys[l][i]
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p14_f32_wire_scalar_simd_pins_the_default_path_bitwise() {
    // Regression pin for the PR 9 knobs' OFF positions: `wire = f32` +
    // `simd = scalar` must be bitwise the default configuration. The
    // default wire IS f32, and auto simd dispatch is licensed only
    // because the AVX2 run-kernels are bitwise-identical to the scalar
    // tiles — which also makes flipping the process-global simd policy
    // mid-suite safe (concurrent tests cannot observe the difference).
    let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
    let b = 4;
    let n = b * part.m;
    let tensor = SymTensor::random(n, 0x145C);
    let mut rng = Rng::new(0x145D);
    let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(n)).collect();
    for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
        for r in [1usize, 4] {
            let xs = &xs[..r];
            let dflt = SttsvPlan::new(&tensor, &part, ExecOpts { mode, ..Default::default() })
                .unwrap()
                .run_multi(xs)
                .unwrap();
            set_simd_policy(SimdPolicy::Scalar);
            let pinned = SttsvPlan::new(
                &tensor,
                &part,
                ExecOpts { mode, wire: WireFormat::F32, ..Default::default() },
            )
            .unwrap()
            .run_multi(xs)
            .unwrap();
            set_simd_policy(SimdPolicy::Auto);
            assert_eq!(pinned.ys, dflt.ys, "{mode:?} r={r}: results must be bitwise equal");
            for p in 0..part.p {
                assert_eq!(
                    pinned.per_proc[p].stats, dflt.per_proc[p].stats,
                    "{mode:?} r={r} proc {p}: counters must be identical"
                );
            }
        }
    }
}

#[test]
fn p15_abft_verify_zero_fault_is_bitwise_with_exact_checksum_words() {
    // ABFT verification is a read-only side computation on the phased
    // sequential path: with nothing corrupt it must change NO result bit,
    // and its wire cost is exactly one integrity word per sweep message
    // (messages unchanged, words += msgs, bytes += wire-width × msgs) —
    // which the ABFT-aware `expected_proc_stats` closed form must also
    // predict. Checksum construction charges one n(n+1)/2-word allreduce
    // per rank, billed separately via `abft_build_stats`. Scrub mode on a
    // clean run is the same bitwise path with zero scrubs.
    let pool = partition_pool();
    check(
        "abft: observationally free when nothing is corrupt",
        0x15AB,
        3,
        |rng: &mut Rng| {
            let part_idx = rng.below(pool.len());
            let b = 2 + rng.below(4); // 2..=5, including non-divisible-by-λ₁
            let wire = if rng.below(2) == 0 { WireFormat::F32 } else { WireFormat::Bf16 };
            let seed = rng.next_u64();
            (part_idx, b, wire, seed)
        },
        |&(part_idx, b, wire, seed)| {
            let part = &pool[part_idx];
            let n = b * part.m;
            let tensor = SymTensor::random(n, seed);
            let mut rng = Rng::new(seed ^ 0x15AB);
            let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(n)).collect();
            let bpw = match wire {
                WireFormat::F32 => 4u64,
                WireFormat::Bf16 => 2,
            };
            for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
                for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
                    for r in [1usize, 4] {
                        let xs = &xs[..r];
                        let plan_for = |abft| {
                            SttsvPlan::new(
                                &tensor,
                                part,
                                ExecOpts {
                                    mode,
                                    transport,
                                    wire,
                                    abft,
                                    overlap: false,
                                    ..Default::default()
                                },
                            )
                        };
                        let base = plan_for(AbftMode::Off).map_err(|e| e.to_string())?;
                        let bo = base.run_multi(xs).map_err(|e| e.to_string())?;
                        let vplan = plan_for(AbftMode::Verify).map_err(|e| e.to_string())?;
                        let vo = vplan.run_multi(xs).map_err(|e| e.to_string())?;
                        let ctx = format!("{transport:?} {mode:?} {wire:?} r={r}");
                        if vo.ys != bo.ys {
                            return Err(format!(
                                "{ctx}: verify-mode results are not bitwise the \
                                 ABFT-off path's"
                            ));
                        }
                        let vx = vplan.expected_proc_stats(r);
                        for p in 0..part.p {
                            let (bs, vs) = (&bo.per_proc[p].stats, &vo.per_proc[p].stats);
                            if (vs.sent_msgs, vs.recv_msgs) != (bs.sent_msgs, bs.recv_msgs) {
                                return Err(format!(
                                    "{ctx} proc {p}: ABFT must not add messages \
                                     (off {bs:?} vs verify {vs:?})"
                                ));
                            }
                            if vs.sent_words != bs.sent_words + bs.sent_msgs
                                || vs.recv_words != bs.recv_words + bs.recv_msgs
                                || vs.sent_bytes != bs.sent_bytes + bpw * bs.sent_msgs
                                || vs.recv_bytes != bs.recv_bytes + bpw * bs.recv_msgs
                            {
                                return Err(format!(
                                    "{ctx} proc {p}: overhead must be exactly one \
                                     integrity word per sweep message \
                                     (off {bs:?} vs verify {vs:?})"
                                ));
                            }
                            if *vs != vx[p] {
                                return Err(format!(
                                    "{ctx} proc {p}: measured counters diverge from \
                                     the ABFT-aware closed form ({vs:?} vs {:?})",
                                    vx[p]
                                ));
                            }
                        }
                        let builds = vplan
                            .abft_build_stats()
                            .ok_or_else(|| format!("{ctx}: ABFT plan lost its build stats"))?;
                        for p in 0..part.p {
                            if builds[p] != allreduce_stats(part.p, p, n * (n + 1) / 2) {
                                return Err(format!(
                                    "{ctx} proc {p}: checksum build comm must be one \
                                     n(n+1)/2-word allreduce ({:?})",
                                    builds[p]
                                ));
                            }
                        }
                        let splan = plan_for(AbftMode::Scrub).map_err(|e| e.to_string())?;
                        let so = splan.run_multi(xs).map_err(|e| e.to_string())?;
                        if so.ys != bo.ys {
                            return Err(format!(
                                "{ctx}: scrub-mode results are not bitwise the \
                                 ABFT-off path's"
                            ));
                        }
                        if splan.abft_scrubs() != 0 {
                            return Err(format!(
                                "{ctx}: zero-fault run scrubbed {} blocks",
                                splan.abft_scrubs()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p15_bit_flips_never_silently_wrong() {
    // SDC containment (§Rob): under injected bit flips every run either
    // returns the bitwise fault-free oracle or fails with a typed
    // `Corrupt` — never a silently wrong answer, never a panic.
    //
    //   wire flips (any bit, ~15% of sweep sends): the per-message
    //     Fletcher integrity word detects EVERY single-bit flip, so
    //     Ok ⇒ no flip fired ⇒ bitwise oracle; a firing is a typed
    //     failure in both verify and scrub mode (wire corruption has no
    //     block to recompute — retry layers own that recovery).
    //   memory flips (exponent MSB, every executed block): flipping bit
    //     30 of ANY f32 changes it by at least 2 (set: |z| < 2 lands in
    //     [2, 4) or beyond, even from zero and subnormals; clear: the
    //     value shrinks by 2¹²⁸ from |z| ≥ 2; exponent 255 results are
    //     inf/NaN, which fail the γ comparison outright) — far beyond
    //     the γ·mass floor — so the per-block check always fires.
    //     Verify mode surfaces `Corrupt`; scrub mode recomputes the
    //     block (bitwise-deterministic) and returns the exact oracle
    //     with every repair counted in `abft_scrubs`.
    //
    // After any failure the same plan must complete a clean rerun
    // bitwise (pools and state survive the unwind, as in P13).
    let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
    let b = 4usize;
    let n = b * part.m;
    let tensor = SymTensor::random(n, 0x15B0);
    let mut rng = Rng::new(0x15B1);
    let xs: Vec<Vec<f32>> = (0..2).map(|_| rng.normal_vec(n)).collect();

    let mut plans = Vec::new(); // per transport: [verify, scrub]
    let mut oracles = Vec::new();
    for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
        let mk = |abft| {
            SttsvPlan::new(
                &tensor,
                &part,
                ExecOpts { transport, abft, overlap: false, ..Default::default() },
            )
            .unwrap()
        };
        oracles.push(mk(AbftMode::Off).run_multi(&xs).unwrap().ys);
        plans.push([mk(AbftMode::Verify), mk(AbftMode::Scrub)]);
    }

    let mut detected = 0u32;
    let mut scrubbed = 0u64;
    check(
        "bit flips: detected or absent, never silently wrong",
        0x15B2,
        24,
        |rng: &mut Rng| {
            let seed = rng.next_u64();
            let t = rng.below(2); // transport index
            let wire_not_mem = rng.below(2) == 0;
            // Wire flips are caught at ANY position (Fletcher); memory
            // flips pin the exponent MSB so the injected error is
            // unconditionally above the detection floor (lower-bit
            // coverage is E19's detection-coverage table, not a
            // never-silently-wrong guarantee).
            let bit = if wire_not_mem { rng.below(32) as u8 } else { 30 };
            (seed, t, wire_not_mem, bit)
        },
        |&(seed, t, wire_not_mem, bit)| {
            let oracle = &oracles[t];
            let chaos = if wire_not_mem {
                FaultPlan::bit_flip(seed, 150_000, 0) // ~15% of sweep sends
            } else {
                FaultPlan::bit_flip(seed, 0, 1_000_000) // every executed block
            }
            .forcing_bit(bit);
            for (mi, plan) in plans[t].iter().enumerate() {
                let kind = if wire_not_mem { "wire" } else { "mem" };
                let ctx = format!("seed {seed:#x} bit {bit} {kind} mode {mi}");
                let scrubs0 = plan.abft_scrubs();
                match plan.run_multi_with(&xs, chaos) {
                    Ok(rep) => {
                        let repaired = plan.abft_scrubs() - scrubs0;
                        scrubbed += repaired;
                        if !wire_not_mem && (mi == 0 || repaired == 0) {
                            // ppm = 10⁶ flips every block: verify mode
                            // cannot succeed, scrub mode cannot succeed
                            // without repairs.
                            return Err(format!(
                                "{ctx}: memory flips fired on every block yet the \
                                 run passed with {repaired} repairs"
                            ));
                        }
                        if rep.ys != *oracle {
                            return Err(format!(
                                "{ctx}: Ok result is not the bitwise fault-free \
                                 oracle — silently wrong"
                            ));
                        }
                    }
                    Err(e) => {
                        detected += 1;
                        let root = match e.downcast_ref::<FailureReport>() {
                            Some(rp) => rp.kind.clone(),
                            None => e.downcast_ref::<SttsvError>().cloned(),
                        };
                        match root {
                            Some(SttsvError::Corrupt { .. }) => {}
                            other => {
                                return Err(format!(
                                    "{ctx}: failure must be typed Corrupt, got \
                                     {other:?} ({e:#})"
                                ));
                            }
                        }
                        let clean = plan
                            .run_multi(&xs)
                            .map_err(|e| format!("{ctx}: clean rerun failed: {e:#}"))?;
                        if clean.ys != *oracle {
                            return Err(format!(
                                "{ctx}: clean rerun after Corrupt is not bitwise"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
    assert!(detected > 0, "no flip was ever detected — injection is not firing");
    assert!(scrubbed > 0, "no block was ever scrubbed — the repair path went untested");
}
