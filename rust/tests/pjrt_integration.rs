//! Integration tests for the PJRT path: AOT artifacts (JAX/Pallas, lowered
//! by `make artifacts`) loaded and executed from Rust, alone and through the
//! full distributed coordinator.
//!
//! Requires `artifacts/manifest.txt` (run `make artifacts`); tests skip with
//! a notice when artifacts are missing so `cargo test` stays runnable in a
//! fresh checkout.

use sttsv::coordinator::{run_sttsv_opts, CommMode, ExecOpts};
use sttsv::partition::TetraPartition;
use sttsv::runtime::{artifacts_dir, block_contract_native, Backend, Engine};
use sttsv::steiner::spherical;
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;

fn have_artifacts() -> bool {
    if artifacts_dir().join("manifest.txt").exists() {
        true
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        false
    }
}

#[test]
fn pjrt_block_kernel_matches_native() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::new(Backend::Pjrt).unwrap();
    for b in [4usize, 8, 16, 32] {
        if !engine.has_artifact(&format!("block_b{b}")) {
            continue;
        }
        let mut rng = Rng::new(b as u64);
        let a = rng.normal_vec(b * b * b);
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let (ci, cj, ck) = engine.block_contract(&a, &u, &v, &w, b).unwrap();
        let (ni, nj, nk) = block_contract_native(&a, &u, &v, &w, b);
        for t in 0..b {
            assert!((ci[t] - ni[t]).abs() < 1e-3, "b={b} ci[{t}]");
            assert!((cj[t] - nj[t]).abs() < 1e-3, "b={b} cj[{t}]");
            assert!((ck[t] - nk[t]).abs() < 1e-3, "b={b} ck[{t}]");
        }
    }
}

#[test]
fn pjrt_batched_kernel_matches_native() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::new(Backend::Pjrt).unwrap();
    let (b, nb) = (8usize, 4usize);
    let mut rng = Rng::new(77);
    let a = rng.normal_vec(nb * b * b * b);
    let (u, v, w) = (
        rng.normal_vec(nb * b),
        rng.normal_vec(nb * b),
        rng.normal_vec(nb * b),
    );
    let (ci, cj, ck) = engine.block_contract_batch(&a, &u, &v, &w, b, nb).unwrap();
    let native = Engine::new(Backend::Native).unwrap();
    let (ni, nj, nk) = native.block_contract_batch(&a, &u, &v, &w, b, nb).unwrap();
    for t in 0..nb * b {
        assert!((ci[t] - ni[t]).abs() < 1e-3);
        assert!((cj[t] - nj[t]).abs() < 1e-3);
        assert!((ck[t] - nk[t]).abs() < 1e-3);
    }
}

#[test]
fn pjrt_dense_sttsv_matches_oracle() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::new(Backend::Pjrt).unwrap();
    let n = 20usize;
    let tensor = SymTensor::random(n, 5);
    let mut a = vec![0.0f32; n * n * n];
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                a[(i * n + j) * n + k] = tensor.get(i, j, k);
            }
        }
    }
    let mut rng = Rng::new(6);
    let x = rng.normal_vec(n);
    let y = engine.dense_sttsv(&a, &x, n).unwrap();
    let want = tensor.sttsv(&x);
    let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
    for i in 0..n {
        assert!((y[i] - want[i]).abs() < 2e-3 * scale, "i={i}");
    }
}

#[test]
fn distributed_sttsv_on_pjrt_backend_q2() {
    if !have_artifacts() {
        return;
    }
    // Full Algorithm 5 with every block contraction running through the AOT
    // Pallas kernel: n = 40, q = 2 (P = 10), b = 8.
    let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
    let b = 8usize;
    let n = b * part.m;
    let tensor = SymTensor::random(n, 7);
    let mut rng = Rng::new(8);
    let x = rng.normal_vec(n);
    let want = tensor.sttsv(&x);
    // packed = true exercises the on-the-fly group-extraction fallback
    // (no resident dense copies); packed = false the resident dense path.
    for batch in [false, true] {
        for packed in [false, true] {
            let rep = run_sttsv_opts(
                &tensor,
                &x,
                &part,
                // overlap: false pins the phased batched dispatch paths the
                // PJRT artifacts are shaped for; the overlap pipeline is
                // backend-agnostic and covered by the native property suite.
                ExecOpts {
                    mode: CommMode::PointToPoint,
                    backend: Backend::Pjrt,
                    batch,
                    packed,
                    overlap: false,
                    ..Default::default()
                },
            )
            .unwrap();
            let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!(
                    (rep.y[i] - want[i]).abs() < 2e-3 * scale,
                    "batch={batch} packed={packed} i={i}: {} vs {}",
                    rep.y[i],
                    want[i]
                );
            }
        }
    }
}

#[test]
fn pjrt_and_native_backends_agree_through_power_method() {
    if !have_artifacts() {
        return;
    }
    use sttsv::apps::power_method;
    let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
    let b = 8usize;
    let n = b * part.m;
    let (tensor, cols) = SymTensor::odeco(n, &[4.0, 1.0], 9);
    let mut x0 = cols[0].clone();
    let mut rng = Rng::new(10);
    for v in x0.iter_mut() {
        *v += 0.2 * rng.normal_f32();
    }
    let opts = |backend| ExecOpts {
        mode: CommMode::PointToPoint,
        backend,
        batch: true,
        packed: false,
        overlap: false,
        ..Default::default()
    };
    let rp = power_method(&tensor, &part, &x0, 40, 1e-6, opts(Backend::Pjrt)).unwrap();
    let rn = power_method(&tensor, &part, &x0, 40, 1e-6, opts(Backend::Native)).unwrap();
    assert!((rp.lambda - 4.0).abs() < 1e-2, "pjrt lambda {}", rp.lambda);
    assert!((rp.lambda - rn.lambda).abs() < 1e-3);
}
