//! Symmetric CP gradient (Algorithm 2) through the distributed stack: the
//! r tensor-times-same-vector products (the bottleneck the paper analyzes)
//! run as distributed STTSVs; a short gradient descent recovers a planted
//! rank-r odeco decomposition.
//!
//!     cargo run --release --example cp_gradient -- [--q 2] [--b 6] [--r 3]
//!         [--steps 40]

use sttsv::apps::{cp_gradient, cp_objective};
use sttsv::coordinator::{CommMode, ExecOpts};
use sttsv::partition::TetraPartition;
use sttsv::runtime::Backend;
use sttsv::steiner::spherical;
use sttsv::tensor::{linalg, SymTensor};
use sttsv::util::cli::Args;
use sttsv::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let q: u64 = args.get_or("q", 2u64);
    let b: usize = args.get_or("b", 6usize);
    let r: usize = args.get_or("r", 3usize);
    let steps: usize = args.get_or("steps", 40usize);
    let backend: Backend = args.get("backend").unwrap_or("native").parse()?;

    let part = TetraPartition::from_steiner(&spherical(q)?)?;
    let n = b * part.m;
    println!("CP gradient descent: q={q} (P={}), n={n}, rank r={r}", part.p);

    // Planted decomposition + perturbed start.
    let lambdas: Vec<f32> = (1..=r).rev().map(|l| l as f32).collect();
    let (tensor, cols) = SymTensor::odeco(n, &lambdas, 17);
    let mut rng = Rng::new(18);
    let mut x: Vec<Vec<f32>> = cols
        .iter()
        .zip(&lambdas)
        .map(|(c, &lam)| {
            // scale so x_l⊗x_l⊗x_l ≈ lam·e⊗e⊗e, then perturb
            let s = lam.powf(1.0 / 3.0);
            c.iter().map(|v| s * v + 0.05 * rng.normal_f32()).collect()
        })
        .collect();

    let opts = ExecOpts { mode: CommMode::PointToPoint, ..ExecOpts::for_backend(backend) };

    let f0 = cp_objective(&tensor, &x);
    println!("initial objective f(X) = {f0:.6}");
    let lr = 0.05f32;
    let mut total_sent = 0u64;
    for step in 0..steps {
        let rep = cp_gradient(&tensor, &part, &x, opts)?;
        total_sent += rep.comm.iter().map(|s| s.sent_words).max().unwrap();
        let gnorm: f32 = rep
            .grad
            .iter()
            .map(|g| linalg::norm(g).powi(2))
            .sum::<f32>()
            .sqrt();
        for (xl, gl) in x.iter_mut().zip(&rep.grad) {
            for (xv, gv) in xl.iter_mut().zip(gl) {
                *xv -= lr * gv;
            }
        }
        if step % 5 == 0 || step == steps - 1 {
            println!(
                "step {:>3}: f(X) = {:<12.6} ||grad|| = {:.3e}",
                step,
                cp_objective(&tensor, &x),
                gnorm
            );
        }
    }
    let f1 = cp_objective(&tensor, &x);
    println!(
        "final objective {f1:.6} (reduced {:.1}%), comm: max sent/proc {} words \
         over {} gradient evals x {} STTSVs",
        100.0 * (1.0 - f1 / f0),
        total_sent,
        steps,
        r
    );
    assert!(f1 < 0.05 * f0, "descent did not reduce the objective enough");
    println!("cp_gradient OK");
    Ok(())
}
