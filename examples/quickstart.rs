//! Quickstart: one distributed STTSV on P = 10 simulated processors.
//!
//!     cargo run --release --example quickstart
//!
//! Builds the q = 2 spherical Steiner partition (P = q(q²+1) = 10), runs
//! Algorithm 5 on a random symmetric tensor, verifies the result against
//! the sequential oracle, and prints the communication accounting next to
//! the paper's Theorem 1 lower bound.

use sttsv::bounds;
use sttsv::coordinator::{run_sttsv, CommMode};
use sttsv::partition::TetraPartition;
use sttsv::runtime::Backend;
use sttsv::steiner::spherical;
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Partition: Steiner (5, 3, 3) system -> 10 tetrahedral blocks.
    let sys = spherical(2)?;
    let part = TetraPartition::from_steiner(&sys)?;
    println!(
        "partition: m = {} row blocks, P = {} processors, λ₁ = {}",
        part.m,
        part.p,
        part.lambda1()
    );

    // 2. Problem: n = 60 (block size b = 12), random symmetric tensor.
    let b = 12;
    let n = b * part.m;
    let tensor = SymTensor::random(n, 42);
    let mut rng = Rng::new(43);
    let x = rng.normal_vec(n);

    // 3. Run Algorithm 5 (point-to-point schedule, native kernels; pass
    //    Backend::Pjrt to use the AOT Pallas kernels after `make artifacts`).
    let rep = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)?;

    // 4. Verify against the sequential Algorithm 4 oracle.
    let want = tensor.sttsv(&x);
    let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
    let max_err = rep
        .y
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs() / scale)
        .fold(0.0f32, f32::max);
    println!("max relative error vs oracle: {max_err:.2e}");
    assert!(max_err < 5e-3);

    // 5. Communication accounting.
    println!(
        "comm/proc: sent {} words, received {} words, {} steps per phase",
        rep.max_sent_words(),
        rep.max_recv_words(),
        rep.steps_per_phase
    );
    println!(
        "paper: closed form {} words, Theorem 1 lower bound {:.1} words",
        bounds::algorithm_words(n, 2),
        bounds::lower_bound_words(n, part.p)
    );
    println!("quickstart OK");
    Ok(())
}
