//! Communication-cost sweep (DESIGN.md E5/E9): measured words per processor
//! on the instrumented simulator vs the paper's closed forms, the Theorem 1
//! lower bound, the All-to-All variant, and the §8 baselines.
//!
//!     cargo run --release --example comm_sweep -- [--scale 4]

use sttsv::bounds;
use sttsv::coordinator::{baselines, run_comm_only, run_sttsv, CommMode};
use sttsv::partition::TetraPartition;
use sttsv::runtime::Backend;
use sttsv::steiner::spherical;
use sttsv::tensor::SymTensor;
use sttsv::util::cli::Args;
use sttsv::util::rng::Rng;
use sttsv::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale: usize = args.get_or("scale", 4usize);

    println!("== E5: Algorithm 5 vs Theorem 1 lower bound (measured words/proc) ==");
    let mut t = Table::new([
        "q",
        "P",
        "n",
        "p2p meas",
        "closed form",
        "Thm1 LB",
        "p2p/LB",
        "a2a meas",
        "a2a/LB",
        "steps/phase",
    ]);
    for q in [2usize, 3, 4, 5] {
        let part = TetraPartition::from_steiner(&spherical(q as u64)?)?;
        let b = q * (q + 1) * scale;
        let n = b * part.m;
        let p2p = run_comm_only(&part, b, CommMode::PointToPoint)?;
        let a2a = run_comm_only(&part, b, CommMode::AllToAll)?;
        let meas = p2p.iter().map(|s| s.sent_words).max().unwrap() as f64;
        let meas_a2a = a2a.iter().map(|s| s.sent_words).max().unwrap() as f64;
        let lb = bounds::lower_bound_words(n, part.p);
        t.row([
            q.to_string(),
            part.p.to_string(),
            n.to_string(),
            fnum(meas),
            fnum(bounds::algorithm_words(n, q)),
            fnum(lb),
            format!("{:.3}", meas / lb),
            fnum(meas_a2a),
            format!("{:.3}", meas_a2a / lb),
            bounds::p2p_steps(q).to_string(),
        ]);
    }
    t.print();

    println!("\n== E9: Algorithm 5 vs baselines (q=2, P=10; measured) ==");
    let part = TetraPartition::from_steiner(&spherical(2)?)?;
    let mut t2 = Table::new([
        "n",
        "Alg5 p2p",
        "naive 3-D grid",
        "sequence (§8)",
        "Alg5/LB",
        "naive/LB",
        "seq/LB",
    ]);
    for b in [6usize, 12, 24, 48] {
        let n = b * part.m;
        let tensor = SymTensor::random(n, 1);
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(n);
        let alg = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)?;
        let naive = baselines::run_naive_grid(&tensor, &x, part.p)?;
        let seq = baselines::run_sequence(&tensor, &x, part.p)?;
        let lb = bounds::lower_bound_words(n, part.p);
        t2.row([
            n.to_string(),
            alg.max_sent_words().to_string(),
            naive.max_sent_words().to_string(),
            seq.max_sent_words().to_string(),
            format!("{:.2}", alg.max_sent_words() as f64 / lb),
            format!("{:.2}", naive.max_sent_words() as f64 / lb),
            format!("{:.2}", seq.max_sent_words() as f64 / lb),
        ]);
    }
    t2.print();
    println!(
        "\nNote: the sequence approach communicates Θ(n) per processor \
         (vs Θ(n/P^(1/3))) and does ~2x the arithmetic (no symmetry); the \
         naive grid tracks the non-symmetric Loomis-Whitney bound instead \
         of Theorem 1."
    );
    println!("comm_sweep OK");
    Ok(())
}
