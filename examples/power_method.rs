//! End-to-end driver (DESIGN.md E8): the higher-order power method
//! (Algorithm 1) for tensor Z-eigenpairs, running every STTSV through the
//! full distributed stack — tetrahedral partition, Theorem 6 schedule,
//! instrumented simulator, and (with --backend pjrt) the AOT Pallas kernels.
//!
//!     cargo run --release --example power_method -- [--q 2] [--b 16]
//!         [--backend native|pjrt] [--iters 60]
//!
//! The workload is an odeco tensor with planted eigenpairs (λ = 5, 2, 1), so
//! convergence is checkable: the method must recover λ = 5 and its vector.

use sttsv::apps::power_method;
use sttsv::bounds;
use sttsv::coordinator::{CommMode, ExecOpts};
use sttsv::partition::TetraPartition;
use sttsv::runtime::Backend;
use sttsv::steiner::spherical;
use sttsv::tensor::{linalg, SymTensor};
use sttsv::util::cli::Args;
use sttsv::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let q: u64 = args.get_or("q", 2u64);
    let b: usize = args.get_or("b", 16usize);
    let iters: usize = args.get_or("iters", 60usize);
    let backend: Backend = args.get("backend").unwrap_or("native").parse()?;

    let part = TetraPartition::from_steiner(&spherical(q)?)?;
    let n = b * part.m;
    println!(
        "power method: q={q} (P={}), n={n}, backend={backend:?}",
        part.p
    );

    let lambdas = [5.0f32, 2.0, 1.0];
    let (tensor, cols) = SymTensor::odeco(n, &lambdas, 7);
    let mut rng = Rng::new(8);
    let mut x0 = cols[0].clone();
    for v in x0.iter_mut() {
        *v += 0.25 * rng.normal_f32();
    }

    let opts = ExecOpts { mode: CommMode::PointToPoint, ..ExecOpts::for_backend(backend) };
    let rep = power_method(&tensor, &part, &x0, iters, 1e-6, opts)?;

    println!("\n# iter   ||y||        lambda       ||dx||");
    for (t, it) in rep.iters.iter().enumerate() {
        println!(
            "{:>6}   {:<10.6}  {:<10.6}  {:.3e}",
            t + 1,
            it.norm,
            it.lambda,
            it.delta
        );
    }

    let align = linalg::dot(&rep.x, &cols[0]).abs();
    println!(
        "\nconverged in {} iters: lambda = {:.6} (planted 5.0), |<x,e1>| = {:.6}",
        rep.iters.len(),
        rep.lambda,
        align
    );
    assert!((rep.lambda - 5.0).abs() < 5e-2, "eigenvalue not recovered");
    assert!(align > 0.999, "eigenvector not recovered");

    let max_sent = rep.comm.iter().map(|s| s.sent_words).max().unwrap();
    let per_iter = max_sent / rep.iters.len() as u64;
    println!(
        "comm: max sent/proc = {} words total, {} per STTSV \
         (closed form {}, Thm 1 lower bound {:.1})",
        max_sent,
        per_iter,
        bounds::algorithm_words(n, q as usize),
        bounds::lower_bound_words(n, part.p)
    );
    println!("power_method OK");
    Ok(())
}
