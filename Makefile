# Top-level convenience targets (the code's "run `make artifacts`" pointers).

.PHONY: artifacts artifacts-quick test test-release-asserts pytest bench \
	bench-smoke bench-overlap bench-compiled bench-e2e bench-e2e-smoke \
	bench-hw bench-hw-smoke bench-serve bench-serve-smoke bench-chaos \
	bench-chaos-smoke bench-precision bench-precision-smoke bench-abft \
	bench-abft-smoke

# AOT-lower the JAX/Pallas kernels (incl. the multi-RHS block_multi_* set)
# to HLO text artifacts for the Rust PJRT backend.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

artifacts-quick:
	cd python && python -m compile.aot --out-dir ../artifacts --quick

# Tier-1 verify.
test:
	cd rust && cargo build --release && cargo test -q

# Release-codegen tests with debug assertions on: runs the
# payload-accounting and panel-aliasing debug_asserts under the same
# optimizations the benches use (mirrors the CI rust-release-asserts job).
test-release-asserts:
	cd rust && RUSTFLAGS="-C debug-assertions" cargo test -q --release

pytest:
	cd python && python -m pytest tests/ -q

# Kernel-throughput r-sweep + E11 packed-vs-dense + E12 overlap-vs-phased;
# writes rust/BENCH_kernel.json.
bench:
	cd rust && cargo bench --bench kernel_throughput

# Fast variant (what CI runs): every path executes, fewer samples.
bench-smoke:
	cd rust && STTSV_BENCH_SMOKE=1 cargo bench --bench kernel_throughput

# Targeted E12 overlap-vs-phased series only (quick sampling), asserting
# comm-cost invariance and steady-state zero allocations inline.
bench-overlap:
	cd rust && STTSV_BENCH_SMOKE=1 STTSV_BENCH_SECTION=e12 \
		cargo bench --bench kernel_throughput

# Targeted E14 compiled-vs-interpreted series only (quick sampling):
# sweep-program throughput vs the packed interpreter and 1-vs-4 compute
# threads, asserting bitwise equality and comm/mults invariance inline.
# Writes rust/BENCH_compiled.json.
bench-compiled:
	cd rust && STTSV_BENCH_SMOKE=1 STTSV_BENCH_SECTION=e14 \
		cargo bench --bench kernel_throughput

# E13 end-to-end power method: resident session vs host-centric loop
# across P in {4, 10, 14}; writes rust/BENCH_e2e.json (per-iteration wall
# clock + comm words) and asserts resident = host + collectives exactly.
bench-e2e:
	cd rust && cargo bench --bench e2e_power_method

# Fast variant (what CI runs): smaller n, fewer iterations and samples;
# every path and every comm assertion still executes.
bench-e2e-smoke:
	cd rust && STTSV_BENCH_SMOKE=1 cargo bench --bench e2e_power_method

# E15 hardware-transport bench: P=2 ping-pong alpha-beta fit per transport
# plus resident power-method wall-clock at P in {4, 10, 14} on both the
# lock-free SPSC backend and the mpsc oracle (comm parity asserted);
# writes rust/BENCH_hw.json. Wants >= P free cores for the spsc numbers.
bench-hw:
	cd rust && cargo bench --bench hw_transport

# Fast variant (what CI runs): fewer widths, reps, and samples; parity
# assertions and the acceptance print still execute.
bench-hw-smoke:
	cd rust && STTSV_BENCH_SMOKE=1 cargo bench --bench hw_transport

# E16 serving-throughput bench: one bursty open-loop query trace replayed
# under serial vs coalescing admission policies at P in {4, 10} on both
# transports; queries/sec + p50/p99 latency per policy, per-batch comm
# asserted = one r-deep STTSV; writes rust/BENCH_serve.json.
bench-serve:
	cd rust && cargo bench --bench serve_throughput

# Fast variant (what CI runs): fewer queries and policies; the cache
# build-once assert, comm asserts, and the acceptance print still execute.
bench-serve-smoke:
	cd rust && STTSV_BENCH_SMOKE=1 cargo bench --bench serve_throughput

# E17 chaos-resilience bench: the E16 bursty trace replayed under a ladder
# of seeded transport fault rates through the robust server (reseeded
# retries, breaker, deadline shedding) at P in {4, 10}; goodput + p50/p99
# + shed/failure accounting per rate; writes rust/BENCH_chaos.json.
bench-chaos:
	cd rust && cargo bench --bench chaos_resilience

# Fast variant (what CI runs): fewer queries and rates; the full-accounting
# assert, zero-rate transparency assert, and acceptance print still execute.
bench-chaos-smoke:
	cd rust && STTSV_BENCH_SMOKE=1 cargo bench --bench chaos_resilience

# E18 precision/SIMD bench: AVX2-vs-scalar run-kernel GF/s (kernel level
# and end to end, bitwise equality asserted), bf16-wire bytes-vs-accuracy,
# and the f32-vs-f64 HOPM conditioning study; writes
# rust/BENCH_precision.json.
bench-precision:
	cd rust && cargo bench --bench precision_simd

# Fast variant (what CI runs): fewer samples; every dispatch path, the
# bitwise and byte-halving asserts, and the acceptance print still execute.
bench-precision-smoke:
	cd rust && STTSV_BENCH_SMOKE=1 cargo bench --bench precision_simd

# E19 ABFT bench: verify/scrub overhead ladder vs the ABFT-off phased
# baseline (P in {4, 10} x both transports x r in {1, 4}) plus the
# detection-coverage table by flipped-bit position (wire flips under f32
# and bf16 wire formats, accumulator flips under the per-block checksum);
# writes rust/BENCH_abft.json.
bench-abft:
	cd rust && cargo bench --bench abft_overhead

# Fast variant (what CI runs): fewer reps, trials, and bit positions; the
# coverage accounting and the acceptance print still execute.
bench-abft-smoke:
	cd rust && STTSV_BENCH_SMOKE=1 cargo bench --bench abft_overhead
