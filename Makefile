# Top-level convenience targets (the code's "run `make artifacts`" pointers).

.PHONY: artifacts artifacts-quick test pytest bench bench-smoke

# AOT-lower the JAX/Pallas kernels (incl. the multi-RHS block_multi_* set)
# to HLO text artifacts for the Rust PJRT backend.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

artifacts-quick:
	cd python && python -m compile.aot --out-dir ../artifacts --quick

# Tier-1 verify.
test:
	cd rust && cargo build --release && cargo test -q

pytest:
	cd python && python -m pytest tests/ -q

# Kernel-throughput r-sweep + E11 packed-vs-dense; writes
# rust/BENCH_kernel.json.
bench:
	cd rust && cargo bench --bench kernel_throughput

# Fast variant (what CI runs): every path executes, fewer samples.
bench-smoke:
	cd rust && STTSV_BENCH_SMOKE=1 cargo bench --bench kernel_throughput
